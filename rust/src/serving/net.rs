//! TCP front-end: a length-prefixed binary protocol over `std::net` that
//! makes the in-process engine — queue, EDF batcher, folded-adapter cache,
//! abort path — reachable from outside the process.
//!
//! # Wire protocol (`MTS1`), all integers little-endian
//!
//! **Handshake.** The client sends the 4-byte magic `MTS1`; the server
//! answers a 20-byte hello: magic `MTS1`, then `u32` seq-len, `u32` vocab,
//! `u32` classes, `u32` num-tasks — everything a client needs to build
//! valid requests without out-of-band configuration.
//!
//! **Request frame** (client → server): `u32` body length, then
//! `u64` client-chosen request id · `u32` task · `u8` priority (lower =
//! more urgent) · `u64` deadline in µs relative to server receipt (0 =
//! none) · `u32` token count · that many `i32` token ids.
//!
//! **Response frame** (server → client): `u32` body length, then `u64` the
//! echoed request id · `u8` status. For status `0` (ok) and `1` (expired —
//! the deadline passed before a worker reached the request; it was shed,
//! not computed): `u32` task · `u64` adapter generation · `u32` batch rows
//! · `u32` logit count · that many `f32` logits (bit-exact: serving logits
//! round-trip the wire unchanged; expired responses carry zero logits).
//! For status `2` (error — validation or shutdown): `u32` message length ·
//! UTF-8 message. Responses are written in request order per connection
//! (pipelining is allowed; a connection may have many requests in flight).
//!
//! # Server lifecycle
//!
//! [`serve_net`] runs inside [`ServingEngine::serve`]'s driver slot: an
//! accept loop (non-blocking + poll, so no self-connect tricks) hands each
//! connection a reader thread (decode → `submit_with` — blocking admission
//! is per-connection TCP backpressure) and a writer thread (await handles
//! in order → encode). **Graceful drain** on shutdown: the accept loop
//! stops taking connections, readers stop consuming new frames (an
//! in-flight frame gets a grace period to finish arriving), writers flush
//! every already-admitted response — workers are still running, so those
//! handles all resolve — and only then are sockets closed. After the
//! driver returns, `serve` closes the queue and the workers drain; no
//! admitted request is ever dropped on a clean shutdown.

use super::engine::ServingEngine;
use super::request::{Response, ResponseHandle, ResponseStatus};
use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Protocol magic + version ("MetaTT Serve v1").
pub const WIRE_MAGIC: [u8; 4] = *b"MTS1";
/// Largest accepted frame body (bytes) — a decode guard, not a tunable.
pub const MAX_FRAME: usize = 1 << 22;

const STATUS_OK: u8 = 0;
const STATUS_EXPIRED: u8 = 1;
const STATUS_ERROR: u8 = 2;

/// How long the accept loop sleeps between polls.
const ACCEPT_POLL: Duration = Duration::from_millis(5);
/// Per-connection read timeout — the granularity at which readers notice
/// the shutdown flag.
const READ_TICK: Duration = Duration::from_millis(25);
/// After shutdown, how long a half-received frame may keep a connection
/// open before it is abandoned (the request was never admitted).
const DRAIN_GRACE: Duration = Duration::from_secs(1);

/// One parsed response frame (client side).
#[derive(Clone, Debug, PartialEq)]
pub struct NetResponse {
    pub id: u64,
    pub status: WireStatus,
    pub task: usize,
    pub generation: u64,
    pub batch_rows: usize,
    pub logits: Vec<f32>,
    /// Populated for `WireStatus::Error` frames.
    pub error: Option<String>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireStatus {
    Ok,
    Expired,
    Error,
}

impl WireStatus {
    fn from_u8(b: u8) -> Result<WireStatus> {
        match b {
            STATUS_OK => Ok(WireStatus::Ok),
            STATUS_EXPIRED => Ok(WireStatus::Expired),
            STATUS_ERROR => Ok(WireStatus::Error),
            other => bail!("unknown response status byte {other}"),
        }
    }
}

/// Server-side counters from one [`serve_net`] run.
#[derive(Clone, Copy, Debug, Default)]
pub struct NetStats {
    pub connections: u64,
    /// Request frames decoded and admitted (or answered with an error).
    pub requests: u64,
}

// ---------------------------------------------------------------------------
// Frame codecs (pure functions — unit-tested without sockets).
// ---------------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Reader over a frame body with bounds-checked typed takes.
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.at + n > self.buf.len() {
            bail!("truncated frame: wanted {n} bytes at offset {}", self.at);
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn done(&self) -> Result<()> {
        if self.at != self.buf.len() {
            bail!("{} trailing bytes after frame body", self.buf.len() - self.at);
        }
        Ok(())
    }
}

/// Encode a full request frame (length prefix included).
pub fn encode_request(
    id: u64,
    task: usize,
    priority: u8,
    deadline_us: u64,
    tokens: &[i32],
) -> Vec<u8> {
    let body_len = 8 + 4 + 1 + 8 + 4 + 4 * tokens.len();
    let mut buf = Vec::with_capacity(4 + body_len);
    put_u32(&mut buf, body_len as u32);
    put_u64(&mut buf, id);
    put_u32(&mut buf, task as u32);
    buf.push(priority);
    put_u64(&mut buf, deadline_us);
    put_u32(&mut buf, tokens.len() as u32);
    for &t in tokens {
        buf.extend_from_slice(&t.to_le_bytes());
    }
    buf
}

/// Decoded request frame body.
pub struct WireRequest {
    pub id: u64,
    pub task: usize,
    pub priority: u8,
    /// Relative deadline in µs; 0 = none.
    pub deadline_us: u64,
    pub tokens: Vec<i32>,
}

/// Decode a request frame body (the bytes after the length prefix).
pub fn decode_request(body: &[u8]) -> Result<WireRequest> {
    let mut c = Cursor::new(body);
    let id = c.u64()?;
    let task = c.u32()? as usize;
    let priority = c.u8()?;
    let deadline_us = c.u64()?;
    let n = c.u32()? as usize;
    if n > MAX_FRAME / 4 {
        bail!("request claims {n} tokens — frame cap exceeded");
    }
    let raw = c.take(4 * n)?;
    let tokens = raw
        .chunks_exact(4)
        .map(|ch| i32::from_le_bytes(ch.try_into().unwrap()))
        .collect();
    c.done()?;
    Ok(WireRequest { id, task, priority, deadline_us, tokens })
}

/// Encode an ok/expired response frame (length prefix included).
pub fn encode_response(
    id: u64,
    status: WireStatus,
    task: usize,
    generation: u64,
    batch_rows: usize,
    logits: &[f32],
) -> Vec<u8> {
    debug_assert!(status != WireStatus::Error, "error frames carry a message instead");
    let body_len = 8 + 1 + 4 + 8 + 4 + 4 + 4 * logits.len();
    let mut buf = Vec::with_capacity(4 + body_len);
    put_u32(&mut buf, body_len as u32);
    put_u64(&mut buf, id);
    buf.push(if status == WireStatus::Ok { STATUS_OK } else { STATUS_EXPIRED });
    put_u32(&mut buf, task as u32);
    put_u64(&mut buf, generation);
    put_u32(&mut buf, batch_rows as u32);
    put_u32(&mut buf, logits.len() as u32);
    for &x in logits {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    buf
}

/// Encode an error response frame (length prefix included).
pub fn encode_error(id: u64, msg: &str) -> Vec<u8> {
    let msg = msg.as_bytes();
    let msg = &msg[..msg.len().min(MAX_FRAME / 2)];
    let body_len = 8 + 1 + 4 + msg.len();
    let mut buf = Vec::with_capacity(4 + body_len);
    put_u32(&mut buf, body_len as u32);
    put_u64(&mut buf, id);
    buf.push(STATUS_ERROR);
    put_u32(&mut buf, msg.len() as u32);
    buf.extend_from_slice(msg);
    buf
}

/// Decode a response frame body (the bytes after the length prefix).
pub fn decode_response(body: &[u8]) -> Result<NetResponse> {
    let mut c = Cursor::new(body);
    let id = c.u64()?;
    let status = WireStatus::from_u8(c.u8()?)?;
    if status == WireStatus::Error {
        let n = c.u32()? as usize;
        let msg = String::from_utf8_lossy(c.take(n)?).into_owned();
        c.done()?;
        return Ok(NetResponse {
            id,
            status,
            task: 0,
            generation: 0,
            batch_rows: 0,
            logits: Vec::new(),
            error: Some(msg),
        });
    }
    let task = c.u32()? as usize;
    let generation = c.u64()?;
    let batch_rows = c.u32()? as usize;
    let n = c.u32()? as usize;
    if n > MAX_FRAME / 4 {
        bail!("response claims {n} logits — frame cap exceeded");
    }
    let raw = c.take(4 * n)?;
    let logits = raw
        .chunks_exact(4)
        .map(|ch| f32::from_le_bytes(ch.try_into().unwrap()))
        .collect();
    c.done()?;
    Ok(NetResponse { id, status, task, generation, batch_rows, logits, error: None })
}

fn encode_hello(engine: &ServingEngine) -> Vec<u8> {
    let mut buf = Vec::with_capacity(20);
    buf.extend_from_slice(&WIRE_MAGIC);
    put_u32(&mut buf, engine.seq_len() as u32);
    put_u32(&mut buf, engine.vocab() as u32);
    put_u32(&mut buf, engine.config().classes as u32);
    put_u32(&mut buf, engine.config().num_tasks as u32);
    buf
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

enum ReadStatus {
    Done,
    /// Clean EOF at a frame boundary.
    Eof,
    /// Shutdown requested while idle (or an in-flight frame overstayed the
    /// drain grace period).
    Idle,
}

/// Fill `buf` from a read-timeout stream. Timeouts are idle ticks: before
/// any byte of `buf` arrives, a tick with the shutdown flag set returns
/// [`ReadStatus::Idle`]; once bytes have arrived the frame is finished
/// regardless (finish admitted work), bounded by [`DRAIN_GRACE`].
fn read_exact_idle(
    stream: &mut TcpStream,
    buf: &mut [u8],
    shutdown: &AtomicBool,
) -> std::io::Result<ReadStatus> {
    let mut filled = 0;
    let mut grace_from: Option<Instant> = None;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 {
                    Ok(ReadStatus::Eof)
                } else {
                    Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "connection closed mid-frame",
                    ))
                };
            }
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if shutdown.load(Ordering::Relaxed) {
                    if filled == 0 {
                        return Ok(ReadStatus::Idle);
                    }
                    let from = *grace_from.get_or_insert_with(Instant::now);
                    if from.elapsed() >= DRAIN_GRACE {
                        return Ok(ReadStatus::Idle);
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(ReadStatus::Done)
}

/// One queued write: the client's id plus either a pending engine handle
/// or an immediate error message.
struct WriteCmd {
    client_id: u64,
    outcome: std::result::Result<ResponseHandle, String>,
}

fn response_frame(client_id: u64, resp: &Response) -> Vec<u8> {
    let status = match resp.status {
        ResponseStatus::Ok => WireStatus::Ok,
        ResponseStatus::Expired => WireStatus::Expired,
    };
    encode_response(client_id, status, resp.task, resp.generation, resp.batch_rows, &resp.logits)
}

/// Await handles in request order and stream frames back. A write failure
/// (client went away) stops writing; remaining handles are dropped, which
/// is harmless — workers ignore dead response channels.
fn writer_loop(stream: &mut TcpStream, rx: mpsc::Receiver<WriteCmd>) {
    for cmd in rx {
        let frame = match cmd.outcome {
            Ok(handle) => match handle.wait() {
                Ok(resp) => response_frame(cmd.client_id, &resp),
                // Dropped before execution (worker failure / abort).
                Err(e) => encode_error(cmd.client_id, &e),
            },
            Err(msg) => encode_error(cmd.client_id, &msg),
        };
        if stream.write_all(&frame).is_err() {
            break;
        }
    }
    let _ = stream.shutdown(Shutdown::Write);
}

/// Read frames, admit them, and feed the writer until EOF, shutdown, or a
/// connection error. Returns the number of request frames handled.
fn reader_loop(
    engine: &ServingEngine,
    stream: &mut TcpStream,
    shutdown: &AtomicBool,
    tx: mpsc::Sender<WriteCmd>,
) -> std::io::Result<u64> {
    let mut served = 0u64;
    loop {
        let mut len4 = [0u8; 4];
        match read_exact_idle(stream, &mut len4, shutdown)? {
            ReadStatus::Done => {}
            ReadStatus::Eof | ReadStatus::Idle => return Ok(served),
        }
        let body_len = u32::from_le_bytes(len4) as usize;
        if body_len > MAX_FRAME {
            // Protocol violation: answer nothing (we cannot trust the
            // stream framing any more) and drop the connection.
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("frame body of {body_len} bytes exceeds the {MAX_FRAME} cap"),
            ));
        }
        let mut body = vec![0u8; body_len];
        match read_exact_idle(stream, &mut body, shutdown)? {
            ReadStatus::Done => {}
            ReadStatus::Eof | ReadStatus::Idle => return Ok(served),
        }
        served += 1;
        let cmd = match decode_request(&body) {
            Ok(wire) => {
                let deadline = if wire.deadline_us == 0 {
                    None
                } else {
                    Some(Duration::from_micros(wire.deadline_us))
                };
                match engine.submit_with(wire.task, wire.tokens, deadline, wire.priority) {
                    Ok(handle) => WriteCmd { client_id: wire.id, outcome: Ok(handle) },
                    Err(e) => WriteCmd { client_id: wire.id, outcome: Err(format!("{e:#}")) },
                }
            }
            // Undecodable body but intact framing: answer an error frame
            // with the best-effort id 0 and keep the connection.
            Err(e) => WriteCmd { client_id: 0, outcome: Err(format!("{e:#}")) },
        };
        if tx.send(cmd).is_err() {
            // Writer died (client closed its read half) — stop reading.
            return Ok(served);
        }
    }
}

fn handle_conn(
    engine: &ServingEngine,
    mut stream: TcpStream,
    shutdown: &AtomicBool,
) -> std::io::Result<u64> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(READ_TICK))?;
    // Handshake: magic in, hello out.
    let mut magic = [0u8; 4];
    match read_exact_idle(&mut stream, &mut magic, shutdown)? {
        ReadStatus::Done => {}
        ReadStatus::Eof | ReadStatus::Idle => return Ok(0),
    }
    if magic != WIRE_MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad protocol magic (want MTS1)",
        ));
    }
    stream.write_all(&encode_hello(engine))?;
    let mut wstream = stream.try_clone()?;
    let (tx, rx) = mpsc::channel::<WriteCmd>();
    std::thread::scope(|scope| {
        let writer = scope.spawn(move || writer_loop(&mut wstream, rx));
        let served = reader_loop(engine, &mut stream, shutdown, tx);
        // `tx` was moved into reader_loop and dropped there: the writer
        // drains every queued response (workers are still running) and
        // exits; joining it completes the flush-before-close drain.
        let _ = writer.join();
        served
    })
}

/// Run the TCP front-end over `listener` until `shutdown` is set. Call
/// inside [`ServingEngine::serve`]'s driver:
///
/// ```ignore
/// engine.serve(|eng| net::serve_net(eng, listener, &shutdown))??;
/// ```
///
/// Connection errors (bad magic, oversized frames, mid-frame EOF) drop
/// that connection only; the listener keeps serving.
pub fn serve_net(
    engine: &ServingEngine,
    listener: TcpListener,
    shutdown: &AtomicBool,
) -> Result<NetStats> {
    listener
        .set_nonblocking(true)
        .map_err(|e| anyhow!("listener nonblocking: {e}"))?;
    let connections = AtomicU64::new(0);
    let requests = AtomicU64::new(0);
    std::thread::scope(|scope| {
        while !shutdown.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    connections.fetch_add(1, Ordering::Relaxed);
                    let requests = &requests;
                    scope.spawn(move || {
                        if let Ok(n) = handle_conn(engine, stream, shutdown) {
                            requests.fetch_add(n, Ordering::Relaxed);
                        }
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(anyhow!("accept failed: {e}")),
            }
        }
        // Scope exit joins every connection handler: readers stop at the
        // shutdown flag, writers flush admitted responses, sockets close.
        Ok(())
    })?;
    Ok(NetStats {
        connections: connections.load(Ordering::Relaxed),
        requests: requests.load(Ordering::Relaxed),
    })
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// What the server advertises at connect time.
#[derive(Clone, Copy, Debug)]
pub struct Hello {
    pub seq: usize,
    pub vocab: usize,
    pub classes: usize,
    pub num_tasks: usize,
}

/// A blocking client connection. Requests may be pipelined: `send` any
/// number, then `recv` responses in the same order.
pub struct NetClient {
    stream: TcpStream,
    pub hello: Hello,
}

impl NetClient {
    /// Connect and handshake.
    pub fn connect(addr: &str) -> Result<NetClient> {
        let mut stream =
            TcpStream::connect(addr).map_err(|e| anyhow!("connect {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        stream
            .write_all(&WIRE_MAGIC)
            .map_err(|e| anyhow!("handshake write: {e}"))?;
        let mut hello = [0u8; 20];
        stream
            .read_exact(&mut hello)
            .map_err(|e| anyhow!("handshake read: {e}"))?;
        if hello[0..4] != WIRE_MAGIC {
            bail!("server answered with bad magic (not a MetaTT serving endpoint?)");
        }
        let word =
            |i: usize| u32::from_le_bytes(hello[i..i + 4].try_into().unwrap()) as usize;
        Ok(NetClient {
            stream,
            hello: Hello {
                seq: word(4),
                vocab: word(8),
                classes: word(12),
                num_tasks: word(16),
            },
        })
    }

    /// Connect with retries — absorbs the server-startup race when the
    /// client is launched right after the server process.
    pub fn connect_retry(addr: &str, timeout: Duration) -> Result<NetClient> {
        let t0 = Instant::now();
        loop {
            match Self::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if t0.elapsed() >= timeout {
                        return Err(e.context(format!("gave up after {timeout:?}")));
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    /// Send one request frame (`deadline_us` 0 = no deadline).
    pub fn send(
        &mut self,
        id: u64,
        task: usize,
        priority: u8,
        deadline_us: u64,
        tokens: &[i32],
    ) -> Result<()> {
        let frame = encode_request(id, task, priority, deadline_us, tokens);
        self.stream.write_all(&frame).map_err(|e| anyhow!("send: {e}"))
    }

    /// Receive the next response frame (blocking).
    pub fn recv(&mut self) -> Result<NetResponse> {
        let mut len4 = [0u8; 4];
        self.stream.read_exact(&mut len4).map_err(|e| anyhow!("recv: {e}"))?;
        let body_len = u32::from_le_bytes(len4) as usize;
        if body_len > MAX_FRAME {
            bail!("response frame of {body_len} bytes exceeds the {MAX_FRAME} cap");
        }
        let mut body = vec![0u8; body_len];
        self.stream.read_exact(&mut body).map_err(|e| anyhow!("recv body: {e}"))?;
        decode_response(&body)
    }

    /// One closed-loop round trip.
    pub fn call(
        &mut self,
        id: u64,
        task: usize,
        priority: u8,
        deadline_us: u64,
        tokens: &[i32],
    ) -> Result<NetResponse> {
        self.send(id, task, priority, deadline_us, tokens)?;
        self.recv()
    }
}

/// What a closed-loop TCP client run measured (client side).
#[derive(Clone, Debug)]
pub struct NetLoadReport {
    pub total: usize,
    /// Computed responses.
    pub ok: usize,
    /// Responses shed with `Expired`.
    pub expired: usize,
    /// Error frames (validation / shutdown).
    pub errors: usize,
    pub elapsed: f64,
    /// Computed responses per second.
    pub throughput_rps: f64,
    /// send → receive round-trip of computed responses, seconds; None when
    /// nothing completed.
    pub latency: Option<crate::bench::Stats>,
}

/// Closed-loop clients over TCP: each thread opens its own connection,
/// derives its deterministic request stream from the server's hello
/// (seq/vocab/num-tasks travel in-band), and round-trips one request at a
/// time. The network twin of [`super::loadgen::run_load`]'s client half —
/// same streams, so a given `(seed, client, index)` asks the same question
/// in-process and over the wire.
pub fn run_net_load(
    addr: &str,
    cfg: &super::loadgen::LoadGenConfig,
    connect_timeout: Duration,
) -> Result<NetLoadReport> {
    if cfg.clients == 0 || cfg.requests_per_client == 0 {
        bail!(
            "net load needs >= 1 client and >= 1 request per client (got {} x {})",
            cfg.clients,
            cfg.requests_per_client
        );
    }
    let deadline_us = cfg.deadline.map_or(0, |d| d.as_micros() as u64);
    let t0 = Instant::now();
    let per_client: Vec<(Vec<f64>, usize, usize)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.clients)
            .map(|client| {
                scope.spawn(move || -> Result<(Vec<f64>, usize, usize)> {
                    let mut conn = NetClient::connect_retry(addr, connect_timeout)?;
                    let stream = super::loadgen::request_stream(
                        cfg,
                        conn.hello.num_tasks,
                        conn.hello.seq,
                        conn.hello.vocab,
                        client,
                        cfg.requests_per_client,
                    );
                    let mut lats = Vec::with_capacity(stream.len());
                    let (mut expired, mut errors) = (0usize, 0usize);
                    for (i, (task, tokens)) in stream.into_iter().enumerate() {
                        let id = ((client as u64) << 32) | i as u64;
                        let sent = Instant::now();
                        let resp =
                            conn.call(id, task, cfg.priority, deadline_us, &tokens)?;
                        if resp.id != id {
                            bail!("response id {} for request {id}", resp.id);
                        }
                        match resp.status {
                            WireStatus::Ok => lats.push(sent.elapsed().as_secs_f64()),
                            WireStatus::Expired => expired += 1,
                            WireStatus::Error => errors += 1,
                        }
                        if cfg.think_us > 0 {
                            std::thread::sleep(Duration::from_micros(cfg.think_us));
                        }
                    }
                    Ok((lats, expired, errors))
                })
            })
            .collect();
        let mut results = Vec::with_capacity(handles.len());
        for h in handles {
            results.push(h.join().map_err(|_| anyhow!("net load client panicked"))??);
        }
        Ok::<_, anyhow::Error>(results)
    })?;
    let elapsed = t0.elapsed().as_secs_f64();
    let mut lats = Vec::new();
    let (mut expired, mut errors) = (0usize, 0usize);
    for (l, e, x) in per_client {
        lats.extend(l);
        expired += e;
        errors += x;
    }
    let ok = lats.len();
    Ok(NetLoadReport {
        total: ok + expired + errors,
        ok,
        expired,
        errors,
        elapsed,
        throughput_rps: ok as f64 / elapsed.max(1e-9),
        latency: if lats.is_empty() {
            None
        } else {
            Some(crate::bench::Stats::from_samples(lats))
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_frame_round_trips() {
        let tokens = vec![1i32, 5, 9, 1023, 0];
        let frame = encode_request(42, 2, 3, 1_500_000, &tokens);
        let body_len = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
        assert_eq!(body_len, frame.len() - 4);
        let wire = decode_request(&frame[4..]).unwrap();
        assert_eq!(wire.id, 42);
        assert_eq!(wire.task, 2);
        assert_eq!(wire.priority, 3);
        assert_eq!(wire.deadline_us, 1_500_000);
        assert_eq!(wire.tokens, tokens);
    }

    #[test]
    fn response_frame_round_trips_logit_bits() {
        // Include values whose bit patterns are easy to corrupt: negative
        // zero, subnormals, and a NaN payload.
        let logits = vec![1.5f32, -0.0, f32::from_bits(0x0000_0001), f32::from_bits(0x7fc0_1234)];
        let frame = encode_response(7, WireStatus::Ok, 1, 3, 4, &logits);
        let got = decode_response(&frame[4..]).unwrap();
        assert_eq!(got.id, 7);
        assert_eq!(got.status, WireStatus::Ok);
        assert_eq!(got.task, 1);
        assert_eq!(got.generation, 3);
        assert_eq!(got.batch_rows, 4);
        assert_eq!(got.logits.len(), logits.len());
        for (a, b) in got.logits.iter().zip(&logits) {
            assert_eq!(a.to_bits(), b.to_bits(), "logit bits must survive the wire");
        }
        let expired = encode_response(8, WireStatus::Expired, 2, 0, 0, &[]);
        let got = decode_response(&expired[4..]).unwrap();
        assert_eq!(got.status, WireStatus::Expired);
        assert!(got.logits.is_empty());
    }

    #[test]
    fn error_frame_round_trips() {
        let frame = encode_error(99, "task 7 out of range (3 served)");
        let got = decode_response(&frame[4..]).unwrap();
        assert_eq!(got.id, 99);
        assert_eq!(got.status, WireStatus::Error);
        assert_eq!(got.error.as_deref(), Some("task 7 out of range (3 served)"));
    }

    #[test]
    fn malformed_frames_are_clean_errors() {
        // Truncated body.
        let frame = encode_request(1, 0, 0, 0, &[1, 2, 3]);
        assert!(decode_request(&frame[4..frame.len() - 2]).is_err());
        // Trailing garbage.
        let mut long = frame[4..].to_vec();
        long.push(0xab);
        assert!(decode_request(&long).is_err());
        // Token count beyond the frame cap.
        let mut huge = Vec::new();
        put_u64(&mut huge, 1);
        put_u32(&mut huge, 0);
        huge.push(0);
        put_u64(&mut huge, 0);
        put_u32(&mut huge, u32::MAX);
        assert!(decode_request(&huge).is_err());
        // Unknown status byte.
        let mut bad = Vec::new();
        put_u64(&mut bad, 1);
        bad.push(17);
        assert!(decode_response(&bad).is_err());
    }
}
