//! Sharded serving: N [`ServingEngine`]s behind one admission seam, with
//! end-to-end supervision.
//!
//! A [`ShardRouter`] owns `shards` engines partitioned into `shards /
//! replicas` **groups**. Replicas inside a group are interchangeable — the
//! caller's `make_tt` closure must hand every member of a group the same
//! adapter state (same-backbone replicas already share frozen panels via
//! `Arc` identity, so a replica costs folded-adapter cache, not backbone
//! memory). Tasks map to groups by residue (`task % groups`), and the
//! affinity policy pins each task to one preferred replica so that
//! replica's folded-adapter LRU stays hot; round-robin is the control
//! arm that spreads a task across all replicas (and their caches).
//!
//! Supervision runs on a heartbeat thread:
//! - **health**: each live shard is probed once per beat. A beat that saw
//!   new worker restarts bumps a consecutive-failure counter (Degraded;
//!   Down at the threshold, like a flapping process under systemd's
//!   `StartLimitBurst`); a clean beat resets it. A wedged shard (fault
//!   injection, or a real stall surfacing as restarts) sits Degraded —
//!   still serving, deprioritized by routing — until the wedge expires.
//! - **failover**: a Down shard is drained and closed exactly once; its
//!   queued requests are `requeue`d — through the urgency-ordered
//!   front-of-line path, never dropped — into the least-loaded surviving
//!   replica. With no survivor they are answered with an explicit `Error`.
//! - **work stealing**: when one replica's queue is ≥ `STEAL_GAP` deeper
//!   than a sibling's, half the gap (the donor's *least urgent* work)
//!   moves over, so a skewed task mix cannot idle half a group.
//! - **degraded-mode admission**: the open-loop path admits by
//!   displacement — when every replica is full, a strictly
//!   higher-priority arrival evicts the lowest class, and the victim is
//!   answered `Expired`. Lowest class shed first, never silently.
//!
//! Routing changes which queue a request waits in, never what is
//! computed: every row's logits depend only on its own tokens, so a
//! 1-shard and an N-replica topology answer the same request stream
//! bit-identically (`tests/router.rs`, `tests/chaos.rs`).

use super::cache::CacheStats;
use super::engine::{
    render_engine_families, EngineConfig, EngineStats, ServeTarget, ServingEngine,
};
use super::request::{
    response_channel, Admit, Pending, Response, ResponseHandle, ResponseStatus,
    StageStamps,
};
use crate::obs::{EventCode, Obs};
use crate::runtime::Backend;
use crate::tt::MetaTt;
use crate::util::fault::{FaultPlan, ShardFault};
use anyhow::{anyhow, bail, Result};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Replica queue-depth gap (in requests) that triggers work stealing.
const STEAL_GAP: usize = 4;

/// How requests pick a replica within their task's group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Pin each task to one preferred replica (`(task / groups) %
    /// replicas`) so its folded adapter stays resident in that replica's
    /// LRU; siblings are fallback only.
    Affinity,
    /// Spread every task across all replicas with a shared cursor — the
    /// cache-cold control arm.
    RoundRobin,
}

impl RoutePolicy {
    /// Parse a `--route` value.
    pub fn parse(s: &str) -> Result<RoutePolicy> {
        match s {
            "affinity" => Ok(RoutePolicy::Affinity),
            "rr" => Ok(RoutePolicy::RoundRobin),
            other => bail!("unknown route policy '{other}' (expected affinity or rr)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::Affinity => "affinity",
            RoutePolicy::RoundRobin => "rr",
        }
    }
}

/// Per-shard health, driven by heartbeat probes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardHealth {
    /// Serving normally; first choice for routing.
    Live,
    /// Serving but suspect (recent restarts, or wedged by fault
    /// injection): routed to only when no Live replica exists.
    Degraded,
    /// Dead. Queue drained + closed; traffic failed over. Terminal —
    /// shards are not resurrected within a serve session.
    Down,
}

const LIVE: u8 = 0;
const DEGRADED: u8 = 1;
const DOWN: u8 = 2;

fn health_of(v: u8) -> ShardHealth {
    match v {
        LIVE => ShardHealth::Live,
        DEGRADED => ShardHealth::Degraded,
        _ => ShardHealth::Down,
    }
}

/// Router configuration (CLI flags map 1:1 onto these).
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Per-shard engine config. `workers` is **per shard**; the fault plan
    /// is shared by every shard and by the supervisor's shard-tick hook.
    pub engine: EngineConfig,
    /// Total shards (engines).
    pub shards: usize,
    /// Same-adapter replicas per group; must divide `shards`.
    pub replicas: usize,
    pub route: RoutePolicy,
    /// Supervisor probe period.
    pub heartbeat: Duration,
    /// Consecutive failing heartbeats before a shard is declared Down.
    pub failure_threshold: u32,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            engine: EngineConfig::default(),
            shards: 2,
            replicas: 2,
            route: RoutePolicy::Affinity,
            heartbeat: Duration::from_millis(50),
            failure_threshold: 3,
        }
    }
}

/// Supervision counters, all monotone (read with [`ShardRouter::router_stats`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Heartbeat sweeps performed.
    pub heartbeats: u64,
    /// Shards declared Down (each counted once).
    pub failovers: u64,
    /// Requests moved off a Down shard into a surviving replica.
    pub moved: u64,
    /// Requests moved between replicas by work stealing.
    pub stolen: u64,
    /// Queued low-priority requests evicted by displacing admission
    /// (each answered `Expired`, never dropped).
    pub displaced: u64,
    /// Requests answered `Error` because their task's whole group was Down.
    pub down_errors: u64,
}

struct RouterStatsInner {
    heartbeats: AtomicU64,
    failovers: AtomicU64,
    moved: AtomicU64,
    stolen: AtomicU64,
    displaced: AtomicU64,
    down_errors: AtomicU64,
}

struct ShardSlot<'b> {
    engine: ServingEngine<'b>,
    group: usize,
    state: AtomicU8,
    /// Consecutive failing heartbeats (reset by a clean beat).
    fails: AtomicU32,
    /// `worker_restarts` high-water mark from the previous beat.
    restarts_seen: AtomicU64,
    /// Wedge expiry on the router's `now_us` clock (0 = not wedged).
    wedged_until_us: AtomicU64,
}

/// A one-release-many-waiters latch: shard serve-threads park their
/// engine drivers on it until the router's own driver returns.
struct Latch {
    done: Mutex<bool>,
    cv: Condvar,
}

impl Latch {
    fn new() -> Latch {
        Latch { done: Mutex::new(false), cv: Condvar::new() }
    }

    fn wait(&self) {
        let mut done = self.done.lock().unwrap();
        while !*done {
            done = self.cv.wait(done).unwrap();
        }
    }

    fn release(&self) {
        *self.done.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

/// The shard router. Construction builds every engine eagerly (bind
/// failures surface before any traffic); [`ShardRouter::serve`] scopes the
/// shard worker pools plus the supervisor around a driver closure, same
/// contract as [`ServingEngine::serve`].
pub struct ShardRouter<'b> {
    cfg: RouterConfig,
    slots: Vec<ShardSlot<'b>>,
    groups: usize,
    /// Shared latency epoch (every shard's `done_us` clock).
    epoch: Instant,
    /// Round-robin cursor (shared across groups; only its parity pattern
    /// matters).
    rr: AtomicUsize,
    stop: AtomicBool,
    rstats: RouterStatsInner,
    /// Ids for synthesized all-replicas-down error responses, minted from
    /// the top of the id space so they can never collide with the
    /// residue-class ids shards assign from the bottom.
    synth_ids: AtomicU64,
}

impl<'b> ShardRouter<'b> {
    /// Build `cfg.shards` engines over one backend. `make_tt(k)` supplies
    /// shard k's adapter chain; replicas of a group MUST receive identical
    /// state (same seed / same checkpoint) — that is what makes failover
    /// bit-transparent. Each shard mints request ids from its own residue
    /// class and stamps `done_us` on one shared epoch.
    pub fn new(
        backend: &'b dyn Backend,
        cfg: RouterConfig,
        mut make_tt: impl FnMut(usize) -> MetaTt,
        backbone: Option<&Path>,
    ) -> Result<ShardRouter<'b>> {
        if cfg.shards < 1 {
            bail!("router config: shards must be >= 1");
        }
        if cfg.replicas < 1 || cfg.shards % cfg.replicas != 0 {
            bail!(
                "router config: replicas ({}) must be >= 1 and divide shards ({})",
                cfg.replicas,
                cfg.shards
            );
        }
        if cfg.failure_threshold < 1 {
            bail!("router config: failure_threshold must be >= 1");
        }
        let groups = cfg.shards / cfg.replicas;
        // Every shard's `done_us` clock, span timestamps, and the router's
        // own event stamps share the observability epoch.
        let epoch = cfg.engine.obs.epoch();
        let mut slots = Vec::with_capacity(cfg.shards);
        for k in 0..cfg.shards {
            let mut engine =
                ServingEngine::new(backend, cfg.engine.clone(), make_tt(k), backbone)?;
            engine.set_id_stride(k as u64, cfg.shards as u64);
            engine.set_epoch(epoch);
            slots.push(ShardSlot {
                engine,
                group: k / cfg.replicas,
                state: AtomicU8::new(LIVE),
                fails: AtomicU32::new(0),
                restarts_seen: AtomicU64::new(0),
                wedged_until_us: AtomicU64::new(0),
            });
        }
        Ok(ShardRouter {
            cfg,
            slots,
            groups,
            epoch,
            rr: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            rstats: RouterStatsInner {
                heartbeats: AtomicU64::new(0),
                failovers: AtomicU64::new(0),
                moved: AtomicU64::new(0),
                stolen: AtomicU64::new(0),
                displaced: AtomicU64::new(0),
                down_errors: AtomicU64::new(0),
            },
            synth_ids: AtomicU64::new(0),
        })
    }

    pub fn config(&self) -> &RouterConfig {
        &self.cfg
    }

    pub fn shards(&self) -> usize {
        self.cfg.shards
    }

    pub fn replicas(&self) -> usize {
        self.cfg.replicas
    }

    pub fn groups(&self) -> usize {
        self.groups
    }

    /// Shard k's current health.
    pub fn health(&self, k: usize) -> ShardHealth {
        health_of(self.slots[k].state.load(Ordering::Relaxed))
    }

    /// Shard k's own execution counters.
    pub fn shard_stats(&self, k: usize) -> EngineStats {
        self.slots[k].engine.stats()
    }

    /// Shard k's folded-adapter cache counters (affinity-vs-rr evidence).
    pub fn shard_cache_stats(&self, k: usize) -> CacheStats {
        self.slots[k].engine.cache_stats()
    }

    /// Supervision counters.
    pub fn router_stats(&self) -> RouterStats {
        RouterStats {
            heartbeats: self.rstats.heartbeats.load(Ordering::Relaxed),
            failovers: self.rstats.failovers.load(Ordering::Relaxed),
            moved: self.rstats.moved.load(Ordering::Relaxed),
            stolen: self.rstats.stolen.load(Ordering::Relaxed),
            displaced: self.rstats.displaced.load(Ordering::Relaxed),
            down_errors: self.rstats.down_errors.load(Ordering::Relaxed),
        }
    }

    /// Folded-adapter cache counters summed across shards.
    pub fn cache_stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for slot in &self.slots {
            let s = slot.engine.cache_stats();
            total.hits += s.hits;
            total.folds += s.folds;
            total.evictions += s.evictions;
            total.reloads += s.reloads;
            total.bytes += s.bytes;
        }
        total
    }

    /// Microseconds on the shared response-stamp clock.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// The observability handle every shard shares (one tracer, one
    /// registry, one epoch).
    pub fn obs(&self) -> &std::sync::Arc<Obs> {
        &self.cfg.engine.obs
    }

    /// Prometheus-style text snapshot of the whole topology: router
    /// supervision counters, per-shard health gauges, engine + cache
    /// families aggregated across shards, then the shared registry
    /// (stage histograms, net counters, tracer meta).
    pub fn metrics_text(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let rs = self.router_stats();
        let counters = [
            ("metatt_router_heartbeats_total", rs.heartbeats),
            ("metatt_router_failovers_total", rs.failovers),
            ("metatt_router_moved_total", rs.moved),
            ("metatt_router_stolen_total", rs.stolen),
            ("metatt_router_displaced_total", rs.displaced),
            ("metatt_router_down_errors_total", rs.down_errors),
        ];
        for (name, v) in counters {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        }
        let _ = writeln!(out, "# HELP metatt_shard_health 0=live 1=degraded 2=down");
        let _ = writeln!(out, "# TYPE metatt_shard_health gauge");
        for (k, slot) in self.slots.iter().enumerate() {
            let state = slot.state.load(Ordering::Relaxed);
            let _ = writeln!(out, "metatt_shard_health{{shard=\"{k}\"}} {state}");
        }
        let depth: usize = self.slots.iter().map(|s| s.engine.queue().len()).sum();
        render_engine_families(
            &mut out,
            &ServeTarget::stats(self),
            &self.cache_stats(),
            ServeTarget::generation(self),
            depth,
        );
        self.cfg.engine.obs.render(&mut out);
        out
    }

    /// Hot-swap every shard's adapter. Replicas of a group must again
    /// receive identical state; each shard bumps its own generation by one,
    /// so per-task generation stamps stay monotone across failover.
    pub fn reload(&self, mut make_tt: impl FnMut(usize) -> MetaTt) -> Result<()> {
        for (k, slot) in self.slots.iter().enumerate() {
            slot.engine.reload(make_tt(k))?;
        }
        Ok(())
    }

    /// Blocking admission (see [`ServingEngine::submit_with`]): route to
    /// the task's group, preferred replica first, Live before Degraded.
    /// A shard that raced to Down mid-submit is skipped; when the whole
    /// group is Down the caller still gets a handle — it resolves to an
    /// explicit `Error` response, never a hang or a silent drop.
    pub fn submit_with(
        &self,
        task: usize,
        tokens: Vec<i32>,
        deadline: Option<Duration>,
        priority: u8,
    ) -> Result<ResponseHandle> {
        let order = self.route_order(task);
        for &k in &order {
            match self.slots[k].engine.submit_with(task, tokens.clone(), deadline, priority)
            {
                Ok(h) => return Ok(h),
                Err(e) => {
                    if self.health(k) == ShardHealth::Down {
                        continue; // lost the race with a failover; next replica
                    }
                    return Err(e); // a real admission error (validation)
                }
            }
        }
        Ok(self.all_down_handle(task))
    }

    /// Blocking admission, default class, no deadline.
    pub fn submit(&self, task: usize, tokens: Vec<i32>) -> Result<ResponseHandle> {
        self.submit_with(task, tokens, None, 0)
    }

    /// Non-blocking admission for open-loop load, with graceful
    /// degradation: each candidate replica is tried in routing order, and
    /// a full queue admits by displacement when the arrival's priority
    /// class strictly outranks the least-urgent queued request — the
    /// victim is answered `Expired` (lowest class shed first, never
    /// silently). `Ok(None)` means every replica was full and nothing was
    /// outranked; all replicas Down again yields an explicit-`Error`
    /// handle.
    pub fn try_submit_with(
        &self,
        task: usize,
        tokens: Vec<i32>,
        deadline: Option<Duration>,
        priority: u8,
    ) -> Result<Option<ResponseHandle>> {
        let order = self.route_order(task);
        let mut any_full = false;
        for &k in &order {
            let slot = &self.slots[k];
            let (p, rx) =
                slot.engine.make_pending(task, tokens.clone(), deadline, priority)?;
            let id = p.req.id;
            match slot.engine.queue().try_submit_displacing(p) {
                Ok(Admit::Admitted(victim)) => {
                    if let Some(v) = victim {
                        self.rstats.displaced.fetch_add(1, Ordering::Relaxed);
                        self.answer_displaced(v);
                    }
                    return Ok(Some(ResponseHandle { id, rx }));
                }
                Ok(Admit::Full) => {
                    any_full = true;
                    continue;
                }
                Err(_) if self.health(k) == ShardHealth::Down => continue,
                Err(e) => return Err(anyhow!(e)),
            }
        }
        if any_full {
            // Whole group saturated even for this class: a plain overload
            // rejection, charged to the preferred replica.
            self.slots[order[0]].engine.note_rejected();
            return Ok(None);
        }
        Ok(Some(self.all_down_handle(task)))
    }

    /// Run the topology: every shard's worker pool plus the supervisor
    /// thread, scoped around `driver`. Graceful-drain contract matches
    /// [`ServingEngine::serve`]; a Down shard's early exit is normal, and
    /// the first *unrecoverable* shard error is propagated after every
    /// pool has joined.
    pub fn serve<R>(&self, driver: impl FnOnce(&Self) -> R) -> Result<R> {
        std::thread::scope(|scope| {
            let latch = Latch::new();
            let shard_threads: Vec<_> = self
                .slots
                .iter()
                .map(|slot| {
                    let latch = &latch;
                    scope.spawn(move || slot.engine.serve(|_| latch.wait()))
                })
                .collect();
            let supervisor = scope.spawn(|| {
                while !self.stop.load(Ordering::Relaxed) {
                    std::thread::sleep(self.cfg.heartbeat);
                    if self.stop.load(Ordering::Relaxed) {
                        break;
                    }
                    self.heartbeat_once();
                }
            });
            // Unwind-guarded like the engine driver: a panicking driver
            // (failing test assertion) must still release the latch, or
            // the scope would join forever.
            let out =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| driver(self)));
            self.stop.store(true, Ordering::Relaxed);
            // Join the supervisor BEFORE releasing the latch: engines only
            // close their queues after release, so no final sweep can
            // mistake an orderly shutdown for a shard self-abort.
            let _ = supervisor.join();
            latch.release();
            let mut first_err = None;
            for t in shard_threads {
                match t.join() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => first_err = first_err.or(Some(e)),
                    Err(_) => {
                        first_err =
                            first_err.or(Some(anyhow!("a shard serve thread panicked")));
                    }
                }
            }
            let out = match out {
                Ok(v) => v,
                Err(payload) => std::panic::resume_unwind(payload),
            };
            match first_err {
                Some(e) => Err(e),
                None => Ok(out),
            }
        })
    }

    /// Run one supervision sweep immediately (tests drive health
    /// transitions deterministically with this instead of sleeping
    /// through heartbeat periods).
    pub fn heartbeat_now(&self) {
        self.heartbeat_once();
    }

    /// One sweep: probe every non-Down shard in index order (fault hook →
    /// self-shutdown check → restart-counter check → state transition),
    /// then rebalance queues within each group.
    fn heartbeat_once(&self) {
        self.rstats.heartbeats.fetch_add(1, Ordering::Relaxed);
        let now_us = self.now_us();
        for k in 0..self.slots.len() {
            let slot = &self.slots[k];
            if self.health(k) == ShardHealth::Down {
                continue;
            }
            match self.cfg.engine.faults.on_shard_tick(k) {
                ShardFault::Down => {
                    self.kill_shard(k);
                    continue;
                }
                ShardFault::Wedge(d) => {
                    slot.wedged_until_us
                        .store(now_us + d.as_micros() as u64, Ordering::Relaxed);
                }
                ShardFault::None => {}
            }
            if slot.engine.queue().is_closed() {
                // The engine aborted itself (unrecoverable worker failure,
                // e.g. a step that cannot re-bind): treat as Down and fail
                // its traffic over.
                self.kill_shard(k);
                continue;
            }
            let restarts = slot.engine.stats().worker_restarts;
            let failing = restarts > slot.restarts_seen.swap(restarts, Ordering::Relaxed);
            if failing {
                let fails = slot.fails.fetch_add(1, Ordering::Relaxed) + 1;
                if fails >= self.cfg.failure_threshold {
                    self.kill_shard(k);
                    continue;
                }
            }
            let wedged = slot.wedged_until_us.load(Ordering::Relaxed) > now_us;
            // Health-transition events fire only on an actual state change
            // (swap + compare), not on every confirming beat.
            if failing || wedged {
                if slot.state.swap(DEGRADED, Ordering::Relaxed) != DEGRADED {
                    let streak = slot.fails.load(Ordering::Relaxed) as u64;
                    self.obs().event(EventCode::ShardDegraded, k as u64, streak);
                }
            } else {
                slot.fails.store(0, Ordering::Relaxed);
                if slot.state.swap(LIVE, Ordering::Relaxed) != LIVE {
                    self.obs().event(EventCode::ShardLive, k as u64, 0);
                }
            }
        }
        self.steal_work();
    }

    /// Declare shard k Down (idempotent — only the first caller drains):
    /// close its queue and fail its admitted requests over to the
    /// least-loaded surviving replica, or answer them explicitly when the
    /// whole group is gone. Either way, zero silent loss.
    fn kill_shard(&self, k: usize) {
        let prev = self.slots[k].state.swap(DOWN, Ordering::Relaxed);
        if prev == DOWN {
            return;
        }
        self.rstats.failovers.fetch_add(1, Ordering::Relaxed);
        self.obs().event(EventCode::ShardDown, k as u64, 0);
        let slot = &self.slots[k];
        // Drain BEFORE close: after close, producers get errors, and
        // whatever landed in between is caught by the post-close drain
        // inside requeue's target (the queue is never read again).
        let mut drained = slot.engine.queue().drain_all();
        slot.engine.queue().close();
        drained.extend(slot.engine.queue().drain_all());
        if drained.is_empty() {
            return;
        }
        let base = slot.group * self.cfg.replicas;
        let survivor = (base..base + self.cfg.replicas)
            .filter(|&j| j != k && self.health(j) != ShardHealth::Down)
            .min_by_key(|&j| {
                (self.health(j) == ShardHealth::Degraded, self.slots[j].engine.queue().len())
            });
        match survivor {
            Some(j) => {
                let moved = drained.len() as u64;
                self.rstats.moved.fetch_add(moved, Ordering::Relaxed);
                self.obs().event(EventCode::FailoverDrain, k as u64, moved);
                // Router-level requeue: payload is (target shard, moved),
                // unlike the engine's per-batch (task, rows) requeues.
                self.obs().event(EventCode::Requeue, j as u64, moved);
                self.slots[j].engine.queue().requeue(drained);
            }
            None => {
                let done_us = self.now_us();
                for p in drained {
                    self.rstats.down_errors.fetch_add(1, Ordering::Relaxed);
                    let _ = p.tx.send(Response {
                        id: p.req.id,
                        task: p.req.task,
                        status: ResponseStatus::Error,
                        logits: Vec::new(),
                        batch_rows: 0,
                        generation: 0,
                        done_us,
                        stamps: StageStamps {
                            admit_us: p.admit_us,
                            ..StageStamps::default()
                        },
                        error: Some(format!(
                            "shard {k} went down with no surviving replica in its group"
                        )),
                    });
                }
            }
        }
    }

    /// Rebalance within each group: move half the queue-depth gap of
    /// least-urgent work from the deepest Live replica to the shallowest.
    fn steal_work(&self) {
        for g in 0..self.groups {
            let base = g * self.cfg.replicas;
            let mut deepest: Option<(usize, usize)> = None;
            let mut shallowest: Option<(usize, usize)> = None;
            for k in base..base + self.cfg.replicas {
                if self.health(k) != ShardHealth::Live {
                    continue;
                }
                let depth = self.slots[k].engine.queue().len();
                if deepest.is_none_or(|(_, d)| depth > d) {
                    deepest = Some((k, depth));
                }
                if shallowest.is_none_or(|(_, d)| depth < d) {
                    shallowest = Some((k, depth));
                }
            }
            let (Some((from, max_d)), Some((to, min_d))) = (deepest, shallowest) else {
                continue;
            };
            if from == to || max_d < min_d + STEAL_GAP {
                continue;
            }
            let stolen = self.slots[from].engine.queue().steal_least_urgent((max_d - min_d) / 2);
            if stolen.is_empty() {
                continue;
            }
            self.rstats.stolen.fetch_add(stolen.len() as u64, Ordering::Relaxed);
            self.obs().event(
                EventCode::WorkSteal,
                ((from as u64) << 32) | to as u64,
                stolen.len() as u64,
            );
            self.slots[to].engine.queue().requeue(stolen);
        }
    }

    /// Candidate shard order for `task`: its group's members, preferred
    /// replica first (policy-dependent), Live pass before Degraded pass,
    /// Down excluded. Empty means the whole group is Down.
    fn route_order(&self, task: usize) -> Vec<usize> {
        let base = (task % self.groups) * self.cfg.replicas;
        let preferred = match self.cfg.route {
            RoutePolicy::Affinity => (task / self.groups) % self.cfg.replicas,
            RoutePolicy::RoundRobin => {
                self.rr.fetch_add(1, Ordering::Relaxed) % self.cfg.replicas
            }
        };
        let mut order = Vec::with_capacity(self.cfg.replicas);
        for pass in [ShardHealth::Live, ShardHealth::Degraded] {
            for i in 0..self.cfg.replicas {
                let k = base + (preferred + i) % self.cfg.replicas;
                if self.health(k) == pass {
                    order.push(k);
                }
            }
        }
        order
    }

    /// Answer a displaced victim: explicit `Expired`, zero compute —
    /// the degraded-mode analogue of queue-side deadline shedding.
    fn answer_displaced(&self, p: Pending) {
        let done_us = self.now_us();
        self.obs().event_at(done_us, EventCode::Displaced, p.req.id, p.req.task as u64);
        let _ = p.tx.send(Response {
            id: p.req.id,
            task: p.req.task,
            status: ResponseStatus::Expired,
            logits: Vec::new(),
            batch_rows: 0,
            generation: 0,
            done_us,
            stamps: StageStamps { admit_us: p.admit_us, ..StageStamps::default() },
            error: Some(
                "displaced by a higher-priority request under shrunken capacity".into(),
            ),
        });
    }

    /// A ready-resolved handle for a request whose whole group is Down.
    /// Synthesized ids are minted from the top of the u64 space so they
    /// never collide with shard-minted residue-class ids.
    fn all_down_handle(&self, task: usize) -> ResponseHandle {
        self.rstats.down_errors.fetch_add(1, Ordering::Relaxed);
        let id = u64::MAX - self.synth_ids.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = response_channel();
        let _ = tx.send(Response {
            id,
            task,
            status: ResponseStatus::Error,
            logits: Vec::new(),
            batch_rows: 0,
            generation: 0,
            done_us: self.now_us(),
            stamps: StageStamps::default(),
            error: Some(format!(
                "task {task}: every replica of its shard group is down"
            )),
        });
        ResponseHandle { id, rx }
    }
}

impl ServeTarget for ShardRouter<'_> {
    fn seq_len(&self) -> usize {
        self.slots[0].engine.seq_len()
    }
    fn vocab(&self) -> usize {
        self.slots[0].engine.vocab()
    }
    fn classes(&self) -> usize {
        self.cfg.engine.classes
    }
    fn num_tasks(&self) -> usize {
        self.cfg.engine.num_tasks
    }
    fn workers(&self) -> usize {
        self.cfg.engine.workers * self.cfg.shards
    }
    fn now_us(&self) -> u64 {
        ShardRouter::now_us(self)
    }
    fn faults(&self) -> &FaultPlan {
        &self.cfg.engine.faults
    }
    fn obs(&self) -> &std::sync::Arc<Obs> {
        ShardRouter::obs(self)
    }
    fn cache_stats(&self) -> CacheStats {
        ShardRouter::cache_stats(self)
    }
    fn metrics_text(&self) -> String {
        ShardRouter::metrics_text(self)
    }
    fn generation(&self) -> u64 {
        self.slots.iter().map(|s| s.engine.generation()).max().unwrap_or(0)
    }
    fn submit_with(
        &self,
        task: usize,
        tokens: Vec<i32>,
        deadline: Option<Duration>,
        priority: u8,
    ) -> Result<ResponseHandle> {
        ShardRouter::submit_with(self, task, tokens, deadline, priority)
    }
    fn try_submit_with(
        &self,
        task: usize,
        tokens: Vec<i32>,
        deadline: Option<Duration>,
        priority: u8,
    ) -> Result<Option<ResponseHandle>> {
        ShardRouter::try_submit_with(self, task, tokens, deadline, priority)
    }
    fn stats(&self) -> EngineStats {
        let mut total = EngineStats {
            batch_hist: vec![0u64; self.cfg.engine.max_batch + 1],
            ..EngineStats::default()
        };
        for slot in &self.slots {
            let s = slot.engine.stats();
            total.batches += s.batches;
            total.requests += s.requests;
            total.shed += s.shed;
            total.rejected += s.rejected;
            total.queue_us_sum += s.queue_us_sum;
            total.queue_us_max = total.queue_us_max.max(s.queue_us_max);
            for (i, n) in s.batch_hist.iter().enumerate() {
                if let Some(slot_n) = total.batch_hist.get_mut(i) {
                    *slot_n += n;
                }
            }
            total.cache_bytes += s.cache_bytes;
            total.worker_restarts += s.worker_restarts;
            total.quarantined += s.quarantined;
            total.requeued += s.requeued;
        }
        total
    }
    fn serve_session<R>(&self, driver: impl FnOnce(&Self) -> R) -> Result<R> {
        ShardRouter::serve(self, driver)
    }
}
