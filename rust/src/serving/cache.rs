//! Per-task folded-adapter cache with LRU eviction, generation counters,
//! and snapshot reads during hot-swap.
//!
//! The store holds the *chain-form* MetaTT adapter of the currently-loaded
//! checkpoint and lazily folds it per task
//! ([`crate::tt::MetaTt::fold_for_serving`], paper §2.4) the first time
//! that task is requested — one fold per (generation, task), LRU-evicted
//! beyond the capacity.
//!
//! **Hot-swap.** [`AdapterStore::reload`] installs a freshly-loaded adapter
//! as a new *generation* without draining in-flight work: readers take a
//! snapshot `Arc` of the current generation (the only shared lock on the
//! read path is a briefly-held `RwLock` read guard around that clone) and
//! keep using it for the batch they are executing even while a reload
//! swaps the current pointer underneath them. Folded factors are immutable
//! once published (`Arc<FoldedAdapter>`), so a batch never observes a
//! half-updated adapter, and the generation id stamped on every response
//! tells clients which checkpoint answered them. (Within one generation,
//! lookups share a per-generation mutex — see [`AdapterStore::get`] for
//! the fold-under-lock trade-off.)

use crate::adapters::AdapterSpec;
use crate::tensor::Tensor;
use crate::tt::MetaTt;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Immutable folded factors for one (generation, task-slice): per
/// (layer, matrix) pairs `(A = α·G1·mid, B = G_last)`, ready for the
/// two-GEMM serving delta.
#[derive(Debug)]
pub struct FoldedAdapter {
    /// Cache key the fold was computed for (the task index for the (4+1)D
    /// task core; 0 for the task-free 4D/5D families).
    pub key: usize,
    /// Generation the factors were folded from.
    pub generation: u64,
    /// `pairs[layer][matrix]` factor pairs.
    pub pairs: Vec<Vec<(Tensor, Tensor)>>,
}

struct LruEntry {
    key: usize,
    stamp: u64,
    folded: Arc<FoldedAdapter>,
}

struct LruInner {
    entries: Vec<LruEntry>,
    clock: u64,
}

/// One loaded checkpoint: the chain-form adapter plus its fold cache.
struct Generation {
    id: u64,
    tt: MetaTt,
    folded: Mutex<LruInner>,
}

/// Cumulative cache counters (monotone across reloads).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Folded-adapter lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to run `fold_for_serving` (misses).
    pub folds: u64,
    /// Entries displaced by LRU pressure.
    pub evictions: u64,
    /// Reloads installed since construction.
    pub reloads: u64,
}

/// The serving engine's adapter state: current generation + fold cache.
pub struct AdapterStore {
    current: RwLock<Arc<Generation>>,
    capacity: usize,
    hits: AtomicU64,
    folds: AtomicU64,
    evictions: AtomicU64,
    reloads: AtomicU64,
}

impl AdapterStore {
    /// Store over an initial adapter; `capacity` bounds the folded entries
    /// kept per generation (>= 1).
    pub fn new(tt: MetaTt, capacity: usize) -> AdapterStore {
        assert!(capacity >= 1, "folded-adapter cache capacity must be >= 1");
        AdapterStore {
            current: RwLock::new(Arc::new(Generation {
                id: 0,
                tt,
                folded: Mutex::new(LruInner { entries: Vec::new(), clock: 0 }),
            })),
            capacity,
            hits: AtomicU64::new(0),
            folds: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
        }
    }

    /// Current generation id (0 for the construction-time adapter).
    pub fn generation(&self) -> u64 {
        self.current.read().unwrap().id
    }

    /// Install a new adapter as the next generation. In-flight batches keep
    /// their snapshot of the old generation; new lookups see the new one.
    /// The fold cache starts empty (old folds describe old parameters).
    pub fn reload(&self, tt: MetaTt) {
        let mut cur = self.current.write().unwrap();
        let id = cur.id + 1;
        *cur = Arc::new(Generation {
            id,
            tt,
            folded: Mutex::new(LruInner { entries: Vec::new(), clock: 0 }),
        });
        self.reloads.fetch_add(1, Ordering::Relaxed);
    }

    /// Folded factors for `task` from the current generation, folding on
    /// first use. The fold runs under the generation's cache lock so each
    /// (generation, task) folds exactly once — the deliberate trade-off is
    /// that while a fold is in progress, other lookups on the same
    /// generation (including hits) wait on that lock; folds are
    /// rank-sized-GEMM cheap and happen once per (generation, task), so a
    /// per-entry once-cell is left as a ROADMAP follow-up rather than
    /// complexity here. Reload hot-swap is unaffected: the generation
    /// snapshot above is taken before this lock.
    pub fn get(&self, task: usize) -> Arc<FoldedAdapter> {
        // Snapshot the generation: after this clone, a concurrent reload
        // cannot invalidate anything this lookup (or the batch built on
        // it) touches.
        let generation = self.current.read().unwrap().clone();
        let key = if generation.tt.distinct_tasks() > 1 { task } else { 0 };
        let mut lru = generation.folded.lock().unwrap();
        lru.clock += 1;
        let stamp = lru.clock;
        if let Some(e) = lru.entries.iter_mut().find(|e| e.key == key) {
            e.stamp = stamp;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(&e.folded);
        }
        self.folds.fetch_add(1, Ordering::Relaxed);
        let folded = Arc::new(FoldedAdapter {
            key,
            generation: generation.id,
            pairs: generation.tt.fold_for_serving(key),
        });
        if lru.entries.len() >= self.capacity {
            let victim = lru
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(i, _)| i)
                .expect("non-empty LRU");
            lru.entries.swap_remove(victim);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        lru.entries.push(LruEntry { key, stamp, folded: Arc::clone(&folded) });
        folded
    }

    /// Cumulative counters (hit rate = hits / (hits + folds)).
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            folds: self.folds.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            reloads: self.reloads.load(Ordering::Relaxed),
        }
    }
}

/// Rebuild the chain-form MetaTT adapter from a checkpoint's named tensors
/// (export layout, the names of [`AdapterSpec::param_specs`]). Shapes are
/// validated up front so a mismatched checkpoint fails with a field-level
/// error instead of a panic deep inside `import_cores`.
pub fn metatt_from_tensors(
    spec: &AdapterSpec,
    tensors: &[(String, Tensor)],
) -> Result<MetaTt, String> {
    let by_name: HashMap<&str, &Tensor> =
        tensors.iter().map(|(n, t)| (n.as_str(), t)).collect();
    let mut cores = Vec::new();
    for p in spec.param_specs() {
        let t = by_name
            .get(p.name.as_str())
            .ok_or_else(|| format!("checkpoint missing adapter core '{}'", p.name))?;
        if t.shape() != &p.shape[..] {
            return Err(format!(
                "adapter core '{}': checkpoint shape {:?}, spec wants {:?} \
                 (adapter {}, rank {})",
                p.name,
                t.shape(),
                p.shape,
                spec.kind.name(),
                spec.rank
            ));
        }
        cores.push((*t).clone());
    }
    // Build a correctly-shaped chain, then overwrite every core with the
    // checkpoint values (seed irrelevant — fully overwritten).
    let mut rng = crate::util::rng::Pcg64::new(0);
    let mut tt = spec.build_metatt_with(&mut rng, None);
    tt.import_cores(&cores);
    Ok(tt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::AdapterKind;
    use crate::config::ModelPreset;
    use crate::tt::{InitStrategy, MetaTtKind};
    use crate::util::rng::Pcg64;

    fn demo_spec(tasks: usize) -> AdapterSpec {
        AdapterSpec::new(
            AdapterKind::MetaTt(MetaTtKind::FourPlusOneD),
            4,
            1.5,
            ModelPreset::Tiny.dims(tasks),
        )
    }

    fn demo_tt(seed: u64, tasks: usize) -> MetaTt {
        let spec = demo_spec(tasks);
        let init = InitStrategy {
            cores: vec![crate::tt::CoreInit::Normal; MetaTtKind::FourPlusOneD.order()],
        };
        spec.build_metatt_with(&mut Pcg64::new(seed), Some(&init))
    }

    #[test]
    fn fold_once_then_hit_then_evict_lru() {
        let store = AdapterStore::new(demo_tt(1, 3), 2);
        let a0 = store.get(0);
        let again = store.get(0);
        assert!(Arc::ptr_eq(&a0, &again), "second lookup must be a cache hit");
        let _a1 = store.get(1);
        assert_eq!(store.stats().folds, 2);
        assert_eq!(store.stats().hits, 1);
        assert_eq!(store.stats().evictions, 0);
        // Touch task 0 so task 1 is the LRU victim, then insert task 2.
        let _ = store.get(0);
        let _ = store.get(2);
        assert_eq!(store.stats().evictions, 1);
        // Task 0 survived (recently used): another lookup is a hit.
        let hits_before = store.stats().hits;
        let _ = store.get(0);
        assert_eq!(store.stats().hits, hits_before + 1);
        // Task 1 was evicted: refetch refolds.
        let folds_before = store.stats().folds;
        let _ = store.get(1);
        assert_eq!(store.stats().folds, folds_before + 1);
    }

    #[test]
    fn reload_bumps_generation_without_invalidating_snapshots() {
        let store = AdapterStore::new(demo_tt(1, 3), 4);
        let old = store.get(1);
        assert_eq!(old.generation, 0);
        store.reload(demo_tt(2, 3));
        assert_eq!(store.generation(), 1);
        assert_eq!(store.stats().reloads, 1);
        // The pre-reload snapshot stays fully usable (in-flight batch).
        assert_eq!(old.pairs.len(), ModelPreset::Tiny.dims(3).layers);
        // New lookups fold from the new parameters.
        let new = store.get(1);
        assert_eq!(new.generation, 1);
        assert!(
            new.pairs[0][0].0 != old.pairs[0][0].0,
            "new generation must carry the reloaded parameters"
        );
    }

    #[test]
    fn task_free_families_share_one_cache_slot() {
        let spec = AdapterSpec::new(
            AdapterKind::MetaTt(MetaTtKind::FourD),
            4,
            1.0,
            ModelPreset::Tiny.dims(1),
        );
        let init = InitStrategy {
            cores: vec![crate::tt::CoreInit::Normal; 4],
        };
        let tt = spec.build_metatt_with(&mut Pcg64::new(9), Some(&init));
        let store = AdapterStore::new(tt, 2);
        let a = store.get(0);
        let b = store.get(5); // any task index maps to the shared slot
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(store.stats().folds, 1);
    }

    #[test]
    fn metatt_from_tensors_roundtrips_and_validates() {
        let tt = demo_tt(3, 3);
        let spec = demo_spec(3);
        let named: Vec<(String, Tensor)> = spec
            .param_specs()
            .iter()
            .zip(tt.export_cores())
            .map(|(p, t)| (p.name.clone(), t))
            .collect();
        let rebuilt = metatt_from_tensors(&spec, &named).unwrap();
        for k in 0..tt.chain.order() {
            assert_eq!(tt.chain.core(k), rebuilt.chain.core(k), "core {k}");
        }
        // Missing core → clean error.
        let err = metatt_from_tensors(&spec, &named[1..]).unwrap_err();
        assert!(err.contains("missing adapter core"), "{err}");
        // Wrong shape → clean error naming the core.
        let mut bad = named.clone();
        bad[0].1 = Tensor::zeros(&[2, 2]);
        let err = metatt_from_tensors(&spec, &bad).unwrap_err();
        assert!(err.contains("g1"), "{err}");
    }
}
