//! Per-task folded-adapter cache with LRU eviction, generation counters,
//! and snapshot reads during hot-swap.
//!
//! The store holds the *chain-form* MetaTT adapter of the currently-loaded
//! checkpoint and lazily folds it per task
//! ([`crate::tt::MetaTt::fold_for_serving`], paper §2.4) the first time
//! that task is requested — one fold per (generation, task), with the
//! folded factors pre-packed at the store's serving dtype
//! ([`crate::runtime::FoldedPairPacked`]) so a worker tick runs the
//! adapter GEMMs straight off resident panels. Entries are LRU-evicted
//! past a **byte** budget: capacity is the resident panel footprint, not
//! an entry count, so an operator can say "folded adapters may hold 64
//! MiB" independent of rank/model/dtype (quantized dtypes fit 2–4× more
//! tasks in the same budget).
//!
//! **Hot-swap.** [`AdapterStore::reload`] installs a freshly-loaded adapter
//! as a new *generation* without draining in-flight work: readers take a
//! snapshot `Arc` of the current generation (the only shared lock on the
//! read path is a briefly-held `RwLock` read guard around that clone) and
//! keep using it for the batch they are executing even while a reload
//! swaps the current pointer underneath them. Folded factors are immutable
//! once published (`Arc<FoldedAdapter>`), so a batch never observes a
//! half-updated adapter, and the generation id stamped on every response
//! tells clients which checkpoint answered them. (Within one generation,
//! lookups share a per-generation mutex — see [`AdapterStore::get`] for
//! the fold-under-lock trade-off.)

use crate::adapters::AdapterSpec;
use crate::obs::{EventCode, Obs};
use crate::runtime::FoldedPairPacked;
use crate::tensor::{DtypeKind, Tensor};
use crate::tt::MetaTt;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Immutable folded factors for one (generation, task-slice): per
/// (layer, matrix) pairs `(A = α·G1·mid, B = G_last)`, pre-packed at the
/// store's serving dtype, ready for the two-GEMM serving delta.
#[derive(Debug)]
pub struct FoldedAdapter {
    /// Cache key the fold was computed for (the task index for the (4+1)D
    /// task core; 0 for the task-free 4D/5D families).
    pub key: usize,
    /// Generation the factors were folded from.
    pub generation: u64,
    /// `pairs[layer][matrix]` factor pairs, packed at the store's dtype.
    pub pairs: Vec<Vec<FoldedPairPacked>>,
    /// Resident panel bytes of every pair — this entry's charge against
    /// the store's byte budget.
    pub bytes: usize,
}

struct LruEntry {
    key: usize,
    stamp: u64,
    folded: Arc<FoldedAdapter>,
}

struct LruInner {
    entries: Vec<LruEntry>,
    clock: u64,
    /// Sum of `folded.bytes` over `entries`.
    bytes: usize,
}

/// One loaded checkpoint: the chain-form adapter plus its fold cache.
struct Generation {
    id: u64,
    tt: MetaTt,
    folded: Mutex<LruInner>,
}

/// Cumulative cache counters (monotone across reloads), plus the current
/// resident-byte gauge.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Folded-adapter lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to run `fold_for_serving` (misses).
    pub folds: u64,
    /// Entries displaced by LRU pressure.
    pub evictions: u64,
    /// Reloads installed since construction.
    pub reloads: u64,
    /// Resident folded-panel bytes of the *current* generation right now
    /// (a gauge, not a counter: bounded by the store's byte capacity
    /// whenever more than one entry is resident).
    pub bytes: u64,
}

/// The serving engine's adapter state: current generation + fold cache.
pub struct AdapterStore {
    current: RwLock<Arc<Generation>>,
    capacity_bytes: usize,
    dtype: DtypeKind,
    hits: AtomicU64,
    folds: AtomicU64,
    evictions: AtomicU64,
    reloads: AtomicU64,
    obs: Arc<Obs>,
}

impl AdapterStore {
    /// Store over an initial adapter; `capacity_bytes` bounds the resident
    /// folded-panel footprint per generation (>= 1; the most recently
    /// folded entry is always kept, so a single oversized fold still
    /// serves). `dtype` is the storage dtype every fold is packed at.
    /// `obs` stamps fold / eviction / hot-swap events when tracing is
    /// armed (a disarmed handle costs one relaxed load per event site).
    pub fn new(
        tt: MetaTt,
        capacity_bytes: usize,
        dtype: DtypeKind,
        obs: Arc<Obs>,
    ) -> AdapterStore {
        assert!(capacity_bytes >= 1, "folded-adapter cache byte capacity must be >= 1");
        AdapterStore {
            current: RwLock::new(Arc::new(Generation {
                id: 0,
                tt,
                folded: Mutex::new(LruInner { entries: Vec::new(), clock: 0, bytes: 0 }),
            })),
            capacity_bytes,
            dtype,
            hits: AtomicU64::new(0),
            folds: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
            obs,
        }
    }

    /// The storage dtype folds are packed at.
    pub fn dtype(&self) -> DtypeKind {
        self.dtype
    }

    /// Current generation id (0 for the construction-time adapter).
    pub fn generation(&self) -> u64 {
        self.current.read().unwrap().id
    }

    /// Install a new adapter as the next generation. In-flight batches keep
    /// their snapshot of the old generation; new lookups see the new one.
    /// The fold cache starts empty (old folds describe old parameters).
    pub fn reload(&self, tt: MetaTt) {
        let mut cur = self.current.write().unwrap();
        let id = cur.id + 1;
        *cur = Arc::new(Generation {
            id,
            tt,
            folded: Mutex::new(LruInner { entries: Vec::new(), clock: 0, bytes: 0 }),
        });
        self.reloads.fetch_add(1, Ordering::Relaxed);
        self.obs.event(EventCode::HotSwap, id, 0);
    }

    /// Folded factors for `task` from the current generation, folding on
    /// first use. The fold runs under the generation's cache lock so each
    /// (generation, task) folds exactly once — the deliberate trade-off is
    /// that while a fold is in progress, other lookups on the same
    /// generation (including hits) wait on that lock; folds are
    /// rank-sized-GEMM cheap and happen once per (generation, task), so a
    /// per-entry once-cell is left as a ROADMAP follow-up rather than
    /// complexity here. Reload hot-swap is unaffected: the generation
    /// snapshot above is taken before this lock.
    pub fn get(&self, task: usize) -> Arc<FoldedAdapter> {
        // Snapshot the generation: after this clone, a concurrent reload
        // cannot invalidate anything this lookup (or the batch built on
        // it) touches.
        let generation = self.current.read().unwrap().clone();
        let key = if generation.tt.distinct_tasks() > 1 { task } else { 0 };
        let mut lru = generation.folded.lock().unwrap();
        lru.clock += 1;
        let stamp = lru.clock;
        if let Some(e) = lru.entries.iter_mut().find(|e| e.key == key) {
            e.stamp = stamp;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(&e.folded);
        }
        self.folds.fetch_add(1, Ordering::Relaxed);
        let dense = generation.tt.fold_for_serving(key);
        let pairs: Vec<Vec<FoldedPairPacked>> = dense
            .iter()
            .map(|row| {
                row.iter().map(|(a, b)| FoldedPairPacked::pack(a, b, self.dtype)).collect()
            })
            .collect();
        let bytes = pairs.iter().flatten().map(|p| p.bytes()).sum();
        self.obs.event(EventCode::CacheFold, key as u64, bytes as u64);
        let folded = Arc::new(FoldedAdapter {
            key,
            generation: generation.id,
            pairs,
            bytes,
        });
        lru.entries.push(LruEntry { key, stamp, folded: Arc::clone(&folded) });
        lru.bytes += bytes;
        // Evict least-recently-used entries until the resident footprint
        // fits the byte budget. The just-inserted entry carries the max
        // stamp, so it is only ever kept — a single fold larger than the
        // whole budget still serves rather than thrashing.
        while lru.bytes > self.capacity_bytes && lru.entries.len() > 1 {
            let victim = lru
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(i, _)| i)
                .expect("non-empty LRU");
            let evicted = lru.entries.swap_remove(victim);
            lru.bytes -= evicted.folded.bytes;
            self.evictions.fetch_add(1, Ordering::Relaxed);
            self.obs.event(
                EventCode::CacheEvict,
                evicted.key as u64,
                evicted.folded.bytes as u64,
            );
        }
        folded
    }

    /// Cumulative counters (hit rate = hits / (hits + folds)) plus the
    /// current generation's resident-byte gauge.
    pub fn stats(&self) -> CacheStats {
        let bytes = {
            let generation = self.current.read().unwrap().clone();
            let lru = generation.folded.lock().unwrap();
            lru.bytes as u64
        };
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            folds: self.folds.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            reloads: self.reloads.load(Ordering::Relaxed),
            bytes,
        }
    }
}

/// Rebuild the chain-form MetaTT adapter from a checkpoint's named tensors
/// (export layout, the names of [`AdapterSpec::param_specs`]). Shapes are
/// validated up front so a mismatched checkpoint fails with a field-level
/// error instead of a panic deep inside `import_cores`.
pub fn metatt_from_tensors(
    spec: &AdapterSpec,
    tensors: &[(String, Tensor)],
) -> Result<MetaTt, String> {
    let by_name: HashMap<&str, &Tensor> =
        tensors.iter().map(|(n, t)| (n.as_str(), t)).collect();
    let mut cores = Vec::new();
    for p in spec.param_specs() {
        let t = by_name
            .get(p.name.as_str())
            .ok_or_else(|| format!("checkpoint missing adapter core '{}'", p.name))?;
        if t.shape() != &p.shape[..] {
            return Err(format!(
                "adapter core '{}': checkpoint shape {:?}, spec wants {:?} \
                 (adapter {}, rank {})",
                p.name,
                t.shape(),
                p.shape,
                spec.kind.name(),
                spec.rank
            ));
        }
        cores.push((*t).clone());
    }
    // Build a correctly-shaped chain, then overwrite every core with the
    // checkpoint values (seed irrelevant — fully overwritten).
    let mut rng = crate::util::rng::Pcg64::new(0);
    let mut tt = spec.build_metatt_with(&mut rng, None);
    tt.import_cores(&cores);
    Ok(tt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::AdapterKind;
    use crate::config::ModelPreset;
    use crate::tt::{InitStrategy, MetaTtKind};
    use crate::util::rng::Pcg64;

    fn test_obs() -> Arc<Obs> {
        Arc::new(Obs::new(false))
    }

    fn demo_spec(tasks: usize) -> AdapterSpec {
        AdapterSpec::new(
            AdapterKind::MetaTt(MetaTtKind::FourPlusOneD),
            4,
            1.5,
            ModelPreset::Tiny.dims(tasks),
        )
    }

    fn demo_tt(seed: u64, tasks: usize) -> MetaTt {
        let spec = demo_spec(tasks);
        let init = InitStrategy {
            cores: vec![crate::tt::CoreInit::Normal; MetaTtKind::FourPlusOneD.order()],
        };
        spec.build_metatt_with(&mut Pcg64::new(seed), Some(&init))
    }

    /// Bytes one folded entry of the demo adapter occupies at `dtype`
    /// (every task of one generation folds to the same shapes).
    fn fold_bytes(dtype: DtypeKind) -> usize {
        let probe = AdapterStore::new(demo_tt(1, 3), usize::MAX, dtype, test_obs());
        probe.get(0).bytes
    }

    #[test]
    fn fold_once_then_hit_then_evict_lru() {
        // Budget exactly two entries' worth of bytes.
        let per_entry = fold_bytes(DtypeKind::F32);
        let store = AdapterStore::new(demo_tt(1, 3), 2 * per_entry, DtypeKind::F32, test_obs());
        let a0 = store.get(0);
        assert_eq!(a0.bytes, per_entry);
        let again = store.get(0);
        assert!(Arc::ptr_eq(&a0, &again), "second lookup must be a cache hit");
        let _a1 = store.get(1);
        assert_eq!(store.stats().folds, 2);
        assert_eq!(store.stats().hits, 1);
        assert_eq!(store.stats().evictions, 0);
        assert_eq!(store.stats().bytes, 2 * per_entry as u64);
        // Touch task 0 so task 1 is the LRU victim, then insert task 2:
        // three entries exceed the byte budget, so one must go.
        let _ = store.get(0);
        let _ = store.get(2);
        assert_eq!(store.stats().evictions, 1);
        assert_eq!(store.stats().bytes, 2 * per_entry as u64);
        // Task 0 survived (recently used): another lookup is a hit.
        let hits_before = store.stats().hits;
        let _ = store.get(0);
        assert_eq!(store.stats().hits, hits_before + 1);
        // Task 1 was evicted: refetch refolds.
        let folds_before = store.stats().folds;
        let _ = store.get(1);
        assert_eq!(store.stats().folds, folds_before + 1);
    }

    #[test]
    fn oversized_fold_is_kept_not_thrashed() {
        // A byte budget smaller than a single entry still serves: the
        // newest fold is always resident; older ones are evicted.
        let store = AdapterStore::new(demo_tt(1, 3), 1, DtypeKind::F32, test_obs());
        let a0 = store.get(0);
        assert!(a0.bytes > 1);
        assert_eq!(store.stats().evictions, 0);
        let _a1 = store.get(1);
        assert_eq!(store.stats().evictions, 1, "task 0 displaced by task 1");
        assert_eq!(store.stats().bytes, a0.bytes as u64, "exactly one entry resident");
        // The in-hand Arc keeps the evicted fold usable for its batch.
        assert_eq!(a0.pairs.len(), ModelPreset::Tiny.dims(3).layers);
    }

    #[test]
    fn quantized_folds_shrink_the_resident_bytes() {
        let f32b = fold_bytes(DtypeKind::F32);
        let bf16b = fold_bytes(DtypeKind::Bf16);
        let i8b = fold_bytes(DtypeKind::I8);
        assert!(bf16b < f32b, "bf16 folds ({bf16b}) must beat f32 ({f32b})");
        assert!(i8b < bf16b, "int8 folds ({i8b}) must beat bf16 ({bf16b})");
    }

    #[test]
    fn reload_bumps_generation_without_invalidating_snapshots() {
        let store = AdapterStore::new(demo_tt(1, 3), 64 << 20, DtypeKind::F32, test_obs());
        let old = store.get(1);
        assert_eq!(old.generation, 0);
        store.reload(demo_tt(2, 3));
        assert_eq!(store.generation(), 1);
        assert_eq!(store.stats().reloads, 1);
        // The pre-reload snapshot stays fully usable (in-flight batch).
        assert_eq!(old.pairs.len(), ModelPreset::Tiny.dims(3).layers);
        // New lookups fold from the new generation (fresh cache).
        let new = store.get(1);
        assert_eq!(new.generation, 1);
        assert!(!Arc::ptr_eq(&new, &old), "reload must refold, not reuse");
    }

    #[test]
    fn task_free_families_share_one_cache_slot() {
        let spec = AdapterSpec::new(
            AdapterKind::MetaTt(MetaTtKind::FourD),
            4,
            1.0,
            ModelPreset::Tiny.dims(1),
        );
        let init = InitStrategy {
            cores: vec![crate::tt::CoreInit::Normal; 4],
        };
        let tt = spec.build_metatt_with(&mut Pcg64::new(9), Some(&init));
        let store = AdapterStore::new(tt, 64 << 20, DtypeKind::F32, test_obs());
        let a = store.get(0);
        let b = store.get(5); // any task index maps to the shared slot
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(store.stats().folds, 1);
    }

    #[test]
    fn metatt_from_tensors_roundtrips_and_validates() {
        let tt = demo_tt(3, 3);
        let spec = demo_spec(3);
        let named: Vec<(String, Tensor)> = spec
            .param_specs()
            .iter()
            .zip(tt.export_cores())
            .map(|(p, t)| (p.name.clone(), t))
            .collect();
        let rebuilt = metatt_from_tensors(&spec, &named).unwrap();
        for k in 0..tt.chain.order() {
            assert_eq!(tt.chain.core(k), rebuilt.chain.core(k), "core {k}");
        }
        // Missing core → clean error.
        let err = metatt_from_tensors(&spec, &named[1..]).unwrap_err();
        assert!(err.contains("missing adapter core"), "{err}");
        // Wrong shape → clean error naming the core.
        let mut bad = named.clone();
        bad[0].1 = Tensor::zeros(&[2, 2]);
        let err = metatt_from_tensors(&spec, &bad).unwrap_err();
        assert!(err.contains("g1"), "{err}");
    }
}
