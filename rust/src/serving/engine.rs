//! The multi-task serving engine: admission queue → dynamic batcher →
//! per-task folded-adapter cache → worker execution on the ref backend.
//!
//! One engine binds a single eval-spec step layout (batch = `max_batch`)
//! against the frozen backbone and serves T tasks through it. Each worker
//! thread binds its **own** step, so warmed serving ticks run concurrently
//! on private workspace arenas (zero heap allocations per tick, pinned by
//! `tests/alloc_regression.rs`) while the thread budget *inside* a tick is
//! the backend's `--threads` kernel banding.
//!
//! Short batches are padded by repeating the first request's row; padding
//! rows are computed and discarded. Every row of the batch depends only on
//! its own tokens, so a response's bits are independent of batch
//! composition — 1-worker and N-worker engines answer a given request
//! stream bit-identically (`tests/serving.rs`).

use super::batcher::BatchPolicy;
use super::cache::{AdapterStore, CacheStats};
use super::request::{
    response_channel, AdmissionQueue, Pending, Request, Response, ResponseHandle,
    ResponseStatus, StageStamps,
};
use crate::adapters::{AdapterKind, AdapterSpec};
use crate::config::ModelPreset;
use crate::obs::{EventCode, Obs};
use crate::runtime::{assemble_frozen, ArtifactSpec, Backend, StepKind};
use crate::tensor::{DtypeKind, Tensor};
use crate::tt::MetaTt;
use crate::util::fault::FaultPlan;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Engine configuration (CLI flags map 1:1 onto these).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub model: ModelPreset,
    /// Adapter family (must be a MetaTT variant — folding is the TT story).
    pub adapter: AdapterKind,
    pub rank: usize,
    pub alpha: f32,
    /// Number of served tasks (classifier-head arity; task-core arity for
    /// the (4+1)D family).
    pub num_tasks: usize,
    /// Classes per task head (synthetic GLUE-style tasks are binary).
    pub classes: usize,
    /// Dynamic-batch cap = the bound eval spec's batch dimension.
    pub max_batch: usize,
    /// How long a short batch waits for same-task stragglers.
    pub batch_deadline: Duration,
    /// Admission-queue bound (producers block beyond it).
    pub queue_capacity: usize,
    /// Worker threads executing batches (each binds its own step).
    pub workers: usize,
    /// Folded-adapter LRU capacity in **bytes** of resident packed panels
    /// per generation (the most recent fold is always kept).
    pub cache_capacity_bytes: usize,
    /// Storage dtype of the serving read path: the bind-time frozen panel
    /// packs and the folded adapter factors (`--serve-dtype`). `F32` is
    /// the bit-exact path; `Bf16`/`I8` trade the dtype's quantization
    /// tolerance for 2–4× less resident panel traffic.
    pub dtype: DtypeKind,
    /// Fault-injection schedule (`--faults` / `METATT_FAULTS`). The
    /// default empty plan disarms every hook at the cost of one relaxed
    /// load per tick — the zero-alloc warmed serving tick is unchanged.
    pub faults: Arc<FaultPlan>,
    /// Observability handle (`--trace` / `METATT_TRACE`), same pattern as
    /// `faults`: the default disarmed handle costs one relaxed load per
    /// hook and allocates no rings. Shared across shards under a router so
    /// every span lands on one timeline ([`crate::obs::Obs::epoch`]).
    pub obs: Arc<Obs>,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig {
            model: ModelPreset::Tiny,
            adapter: AdapterKind::MetaTt(crate::tt::MetaTtKind::FourPlusOneD),
            rank: 8,
            alpha: 2.0,
            num_tasks: 3,
            classes: 2,
            max_batch: 8,
            batch_deadline: Duration::from_millis(2),
            queue_capacity: 256,
            workers: 2,
            cache_capacity_bytes: 64 << 20,
            dtype: DtypeKind::F32,
            faults: Arc::new(FaultPlan::empty()),
            obs: Arc::new(Obs::new(false)),
        }
    }
}

/// Execution counters, all monotone (read with [`ServingEngine::stats`]).
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// Batches executed (shed-only drains are not batches).
    pub batches: u64,
    /// Requests computed (excludes shed).
    pub requests: u64,
    /// Requests shed at the queue: their deadline passed before a worker
    /// reached them, so they were answered `Expired` with zero compute.
    pub shed: u64,
    /// Open-loop submissions refused because the admission queue was full
    /// (`try_submit_with`); blocking `submit` never increments this.
    pub rejected: u64,
    /// Total µs *computed* requests spent queued (admission → drain). Shed
    /// requests are excluded — their wait ends in an answer, not service,
    /// and counting them would make overload look like queue-delay.
    pub queue_us_sum: u64,
    /// Largest single computed-request queue delay seen, in µs.
    pub queue_us_max: u64,
    /// `hist[k]` = batches that carried exactly k real requests (index 0
    /// unused).
    pub batch_hist: Vec<u64>,
    /// Resident folded-adapter panel bytes right now (a gauge mirrored
    /// from [`CacheStats::bytes`], bounded by
    /// [`EngineConfig::cache_capacity_bytes`] past the first fold).
    pub cache_bytes: u64,
    /// Worker supervision events: a batch execution panicked (or errored)
    /// and the worker re-bound a fresh step instead of aborting the queue.
    pub worker_restarts: u64,
    /// Requests answered `Error` after repeatedly failing execution (their
    /// batch panicked, the solo retry panicked again).
    pub quarantined: u64,
    /// Requests put back on the queue by supervision (each failed attempt
    /// counts every batch member once).
    pub requeued: u64,
}

impl EngineStats {
    /// Mean queue delay of computed requests, in seconds.
    pub fn queue_wait_mean_s(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.queue_us_sum as f64 / self.requests as f64 * 1e-6
        }
    }

    /// Counter deltas since `base` (an earlier snapshot of the same
    /// engine). Lets a measured window — e.g. post-warmup load generation —
    /// report its own traffic instead of cumulative-since-construction
    /// numbers. `queue_us_max` is the window's running max only when it
    /// grew; a stale max from before the window cannot be subtracted out,
    /// so it is reported as 0 if unchanged (no new maximum in-window).
    pub fn delta_since(&self, base: &EngineStats) -> EngineStats {
        let hist = self
            .batch_hist
            .iter()
            .enumerate()
            .map(|(i, &n)| n - base.batch_hist.get(i).copied().unwrap_or(0))
            .collect();
        EngineStats {
            batches: self.batches - base.batches,
            requests: self.requests - base.requests,
            shed: self.shed - base.shed,
            rejected: self.rejected - base.rejected,
            queue_us_sum: self.queue_us_sum - base.queue_us_sum,
            queue_us_max: if self.queue_us_max > base.queue_us_max {
                self.queue_us_max
            } else {
                0
            },
            batch_hist: hist,
            // A gauge, not a counter: the window reports the current value.
            cache_bytes: self.cache_bytes,
            worker_restarts: self.worker_restarts - base.worker_restarts,
            quarantined: self.quarantined - base.quarantined,
            requeued: self.requeued - base.requeued,
        }
    }
}

struct StatsInner {
    batches: AtomicU64,
    requests: AtomicU64,
    shed: AtomicU64,
    rejected: AtomicU64,
    queue_us_sum: AtomicU64,
    queue_us_max: AtomicU64,
    hist: Mutex<Vec<u64>>,
    worker_restarts: AtomicU64,
    quarantined: AtomicU64,
    requeued: AtomicU64,
}

/// The engine. Holds no worker threads itself — [`ServingEngine::serve`]
/// scopes them around a caller-supplied driver closure, so the engine can
/// borrow the backend and still be used from plain tests and the CLI.
pub struct ServingEngine<'b> {
    backend: &'b dyn Backend,
    cfg: EngineConfig,
    spec: ArtifactSpec,
    seq: usize,
    vocab: usize,
    frozen: Arc<HashMap<String, Tensor>>,
    store: AdapterStore,
    queue: AdmissionQueue,
    policy: BatchPolicy,
    stats: StatsInner,
    next_id: AtomicU64,
    /// Request-id increment (1 standalone; the shard count under a
    /// [`super::router::ShardRouter`], which gives shard k the residue
    /// class k so ids stay globally unique across the topology).
    id_step: u64,
    /// The zero point of [`Self::now_us`] and every [`Response::done_us`]
    /// stamp. Copied from [`Obs::epoch`] at construction so span
    /// timestamps and stage stamps share one clock.
    epoch: Instant,
    /// Cached registry handles: per-task computed-request counters
    /// (armed-path increments never touch the registry lock).
    task_requests: Vec<Arc<crate::obs::Counter>>,
}

impl<'b> ServingEngine<'b> {
    /// Build an engine over `backend`, serving `tt` (chain form, typically
    /// rebuilt from a checkpoint via
    /// [`super::cache::metatt_from_tensors`]). `backbone` points at a
    /// pretrained-backbone checkpoint; None falls back to the seeded
    /// deterministic backbone (same rule as training).
    pub fn new(
        backend: &'b dyn Backend,
        cfg: EngineConfig,
        tt: MetaTt,
        backbone: Option<&Path>,
    ) -> Result<ServingEngine<'b>> {
        if cfg.max_batch < 1 || cfg.workers < 1 || cfg.num_tasks < 1 || cfg.classes < 1 {
            bail!("serving config: max_batch, workers, num_tasks, classes must all be >= 1");
        }
        if cfg.queue_capacity < 1 || cfg.cache_capacity_bytes < 1 {
            bail!("serving config: queue_capacity and cache_capacity_bytes must be >= 1");
        }
        let AdapterKind::MetaTt(kind) = cfg.adapter else {
            bail!(
                "serving folds TT adapters only (got '{}'); train MetaTT variants \
                 for multi-task serving",
                cfg.adapter.name()
            );
        };
        let dims = cfg.model.dims(cfg.num_tasks);
        validate_adapter_fit(kind, &cfg, &tt)?;
        let spec = ArtifactSpec {
            step: StepKind::Eval,
            model: cfg.model.name().to_string(),
            adapter: cfg.adapter.name(),
            rank: cfg.rank,
            classes: cfg.classes,
            tasks: cfg.num_tasks,
            batch: cfg.max_batch,
            seq: dims.max_seq,
        };
        let entry = backend.entry(&spec)?;
        let frozen = Arc::new(assemble_frozen(&entry, backbone, cfg.model)?);
        let store =
            AdapterStore::new(tt, cfg.cache_capacity_bytes, cfg.dtype, cfg.obs.clone());
        let queue = AdmissionQueue::new(cfg.queue_capacity);
        let task_requests = (0..cfg.num_tasks)
            .map(|t| {
                cfg.obs.registry().counter(
                    "metatt_task_requests_total",
                    "requests computed, by task",
                    &format!("task=\"{t}\""),
                )
            })
            .collect();
        let epoch = cfg.obs.epoch();
        let policy = BatchPolicy { max_batch: cfg.max_batch, deadline: cfg.batch_deadline };
        let hist = vec![0u64; cfg.max_batch + 1];
        Ok(ServingEngine {
            backend,
            cfg,
            spec,
            seq: dims.max_seq,
            vocab: dims.vocab,
            frozen,
            store,
            queue,
            policy,
            stats: StatsInner {
                batches: AtomicU64::new(0),
                requests: AtomicU64::new(0),
                shed: AtomicU64::new(0),
                rejected: AtomicU64::new(0),
                queue_us_sum: AtomicU64::new(0),
                queue_us_max: AtomicU64::new(0),
                hist: Mutex::new(hist),
                worker_restarts: AtomicU64::new(0),
                quarantined: AtomicU64::new(0),
                requeued: AtomicU64::new(0),
            },
            next_id: AtomicU64::new(0),
            id_step: 1,
            epoch,
            task_requests,
        })
    }

    /// Admission-queue seam for the shard router: failover drains, work
    /// stealing, and displacing admission all operate on the raw queue
    /// (`serving::router`).
    pub(crate) fn queue(&self) -> &AdmissionQueue {
        &self.queue
    }

    /// Count an admission rejection that happened outside
    /// [`Self::try_submit_with`] (the router's displacing path).
    pub(crate) fn note_rejected(&self) {
        self.stats.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Re-base request-id assignment to `start, start + stride, …`. Shard k
    /// of an N-shard router takes `(k, N)` so every shard mints ids from a
    /// disjoint residue class — responses stay globally unique without any
    /// cross-shard coordination on the hot path.
    pub(crate) fn set_id_stride(&mut self, start: u64, stride: u64) {
        self.next_id = AtomicU64::new(start);
        self.id_step = stride.max(1);
    }

    /// Share one latency epoch across shards so every shard's
    /// [`Response::done_us`] stamps land on a single comparable clock.
    pub(crate) fn set_epoch(&mut self, epoch: Instant) {
        self.epoch = epoch;
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Sequence length every request must be tokenized to.
    pub fn seq_len(&self) -> usize {
        self.seq
    }

    /// Vocabulary bound for request token ids.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Current adapter-store generation (bumped by [`Self::reload`]).
    pub fn generation(&self) -> u64 {
        self.store.generation()
    }

    /// Folded-adapter cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.store.stats()
    }

    /// Execution counters (batch-size histogram index = real requests).
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            batches: self.stats.batches.load(Ordering::Relaxed),
            requests: self.stats.requests.load(Ordering::Relaxed),
            shed: self.stats.shed.load(Ordering::Relaxed),
            rejected: self.stats.rejected.load(Ordering::Relaxed),
            queue_us_sum: self.stats.queue_us_sum.load(Ordering::Relaxed),
            queue_us_max: self.stats.queue_us_max.load(Ordering::Relaxed),
            batch_hist: self.stats.hist.lock().unwrap().clone(),
            cache_bytes: self.store.stats().bytes,
            worker_restarts: self.stats.worker_restarts.load(Ordering::Relaxed),
            quarantined: self.stats.quarantined.load(Ordering::Relaxed),
            requeued: self.stats.requeued.load(Ordering::Relaxed),
        }
    }

    /// The engine's fault-injection plan (threaded into the TCP front-end's
    /// per-frame hook by [`super::net::serve_net`]).
    pub fn faults(&self) -> &FaultPlan {
        &self.cfg.faults
    }

    /// The observability handle (PR 10) — span tracer, metrics registry,
    /// and protocol-error counters for the front-ends.
    pub fn obs(&self) -> &Arc<Obs> {
        &self.cfg.obs
    }

    /// Prometheus-style text snapshot: engine counters, cache counters,
    /// and everything in the obs registry (stage histograms, per-task
    /// counters, net protocol errors, tracer meta). Served live over the
    /// MTS1 `STAT` admin frame and dumped by `--metrics-out`.
    pub fn metrics_text(&self) -> String {
        let mut out = String::new();
        render_engine_families(
            &mut out,
            &self.stats(),
            &self.cache_stats(),
            self.generation(),
            self.queue.len(),
        );
        self.cfg.obs.render(&mut out);
        out
    }

    /// Microseconds since engine construction — the clock every
    /// [`Response::done_us`] is stamped against. Load generators measure
    /// submit→done on this clock so a lagging collector thread cannot
    /// inflate latencies.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Hot-swap the adapter to a new chain state (e.g. a freshly-loaded
    /// checkpoint) without draining in-flight batches: they finish on the
    /// generation they snapshotted; subsequent batches fold from the new
    /// one.
    pub fn reload(&self, tt: MetaTt) -> Result<()> {
        let AdapterKind::MetaTt(kind) = self.cfg.adapter else {
            unreachable!("constructor enforces a MetaTT adapter");
        };
        validate_adapter_fit(kind, &self.cfg, &tt)?;
        self.store.reload(tt);
        Ok(())
    }

    /// Admit one request (blocking while the queue is full). The returned
    /// handle resolves to the [`Response`] once a worker's batch carried it.
    /// No deadline, default priority — see [`Self::submit_with`].
    pub fn submit(&self, task: usize, tokens: Vec<i32>) -> Result<ResponseHandle> {
        self.submit_with(task, tokens, None, 0)
    }

    /// Admit one request with an optional relative deadline and a priority
    /// class (lower = more urgent), blocking while the queue is full. The
    /// deadline becomes absolute at admission; a worker that reaches the
    /// request at or after it answers `Expired` without computing.
    pub fn submit_with(
        &self,
        task: usize,
        tokens: Vec<i32>,
        deadline: Option<Duration>,
        priority: u8,
    ) -> Result<ResponseHandle> {
        let (p, rx) = self.make_pending(task, tokens, deadline, priority)?;
        let id = p.req.id;
        self.queue.submit(p).map_err(|e| anyhow!(e))?;
        Ok(ResponseHandle { id, rx })
    }

    /// Non-blocking admission for open-loop load: `Ok(None)` means the
    /// queue was full and the request was rejected (counted in
    /// [`EngineStats::rejected`]) — the arrival process never blocks, which
    /// is what makes offered load independent of service rate.
    pub fn try_submit_with(
        &self,
        task: usize,
        tokens: Vec<i32>,
        deadline: Option<Duration>,
        priority: u8,
    ) -> Result<Option<ResponseHandle>> {
        let (p, rx) = self.make_pending(task, tokens, deadline, priority)?;
        let id = p.req.id;
        match self.queue.try_submit(p).map_err(|e| anyhow!(e))? {
            true => Ok(Some(ResponseHandle { id, rx })),
            false => {
                self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                Ok(None)
            }
        }
    }

    pub(crate) fn make_pending(
        &self,
        task: usize,
        tokens: Vec<i32>,
        deadline: Option<Duration>,
        priority: u8,
    ) -> Result<(Pending, std::sync::mpsc::Receiver<Response>)> {
        if task >= self.cfg.num_tasks {
            bail!("task {task} out of range ({} served)", self.cfg.num_tasks);
        }
        if tokens.len() != self.seq {
            bail!("request has {} tokens, spec wants {}", tokens.len(), self.seq);
        }
        if let Some(&t) = tokens.iter().find(|&&t| t < 0 || t as usize >= self.vocab) {
            bail!("token id {t} outside [0, {})", self.vocab);
        }
        let id = self.next_id.fetch_add(self.id_step, Ordering::Relaxed);
        let (tx, rx) = response_channel();
        let now = Instant::now();
        let admit_us = self.now_us();
        self.cfg.obs.event_at(admit_us, EventCode::Admit, id, task as u64);
        Ok((
            Pending {
                req: Request { id, task, tokens, priority },
                tx,
                enqueued: now,
                deadline: deadline.map(|d| now + d),
                admit_us,
                batch_us: 0,
                panics: 0,
                solo: false,
            },
            rx,
        ))
    }

    /// Run the engine: spawn the worker pool, hand control to `driver`
    /// (submit requests, reload checkpoints, …), then close the queue,
    /// drain, and join. The close-then-join sequence is the **graceful
    /// drain**: new submissions fail, but workers finish every
    /// already-admitted request — computing live ones, answering expired
    /// ones with `Expired` — before exiting, so no admitted request is
    /// ever left unanswered on a clean shutdown (pinned in
    /// `tests/serving.rs`). Batch execution failures — errors *or* panics —
    /// are **supervised** (PR 8): the worker counts a restart, requeues the
    /// in-flight batch, and re-binds a fresh step; a request whose batch
    /// fails twice is retried solo, and a solo failure answers it with an
    /// explicit `Error` status (quarantine) while its former batch-mates
    /// succeed. Only an unrecoverable worker failure — a step that cannot
    /// (re)bind — aborts the queue (close + drop every queued request), so
    /// even then clients observe a receive error instead of hanging and
    /// blocked producers wake up.
    pub fn serve<R>(&self, driver: impl FnOnce(&Self) -> R) -> Result<R> {
        std::thread::scope(|scope| {
            let workers: Vec<_> = (0..self.cfg.workers)
                .map(|_| {
                    scope.spawn(|| {
                        // catch_unwind so a panicking worker still runs the
                        // fail-fast abort (a poisoned unwrap must not leave
                        // admitted requests waiting on no one).
                        let res = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| self.worker_loop()),
                        );
                        match res {
                            Ok(Ok(())) => Ok(()),
                            Ok(Err(e)) => {
                                self.queue.abort();
                                Err(e)
                            }
                            Err(_) => {
                                self.queue.abort();
                                Err(anyhow!("a serving worker panicked"))
                            }
                        }
                    })
                })
                .collect();
            // The driver is unwind-guarded too: a panicking driver (e.g. a
            // failing test assertion) must still close the queue, or the
            // scope would block forever joining workers parked on it. The
            // panic is re-raised after the pool has shut down.
            let out =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| driver(self)));
            self.queue.close();
            let mut first_err = None;
            for w in workers {
                match w.join() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => first_err = first_err.or(Some(e)),
                    Err(_) => {
                        first_err =
                            first_err.or(Some(anyhow!("a serving worker panicked")));
                    }
                }
            }
            let out = match out {
                Ok(v) => v,
                Err(payload) => std::panic::resume_unwind(payload),
            };
            match first_err {
                Some(e) => Err(e),
                None => Ok(out),
            }
        })
    }

    /// One worker: bind a private step, then drain → shed-answer →
    /// fold-lookup → execute → fulfil until the queue closes. The token and
    /// logit buffers are reused across ticks, so a warmed tick's only
    /// allocations are the per-response logit vectors handed to clients
    /// (the supervision guard's success path is allocation-free).
    fn worker_loop(&self) -> Result<()> {
        let mut step = self.backend.bind_serve(&self.spec, &self.frozen, self.cfg.dtype)?;
        let (b, s, classes) = (self.cfg.max_batch, self.seq, self.cfg.classes);
        let mut tokens = vec![0i32; b * s];
        let mut logits = vec![0f32; b * classes];
        while let Some(drained) = self.policy.next_batch(&self.queue) {
            // Dead work first: answer shed requests with an explicit
            // Expired status and zero compute.
            if !drained.shed.is_empty() {
                self.stats.shed.fetch_add(drained.shed.len() as u64, Ordering::Relaxed);
                let done_us = self.now_us();
                for p in drained.shed {
                    self.cfg.obs.event_at(done_us, EventCode::Shed, p.req.id, p.req.task as u64);
                    let _ = p.tx.send(Response {
                        id: p.req.id,
                        task: p.req.task,
                        status: ResponseStatus::Expired,
                        logits: Vec::new(),
                        batch_rows: 0,
                        generation: 0,
                        done_us,
                        stamps: StageStamps { admit_us: p.admit_us, ..StageStamps::default() },
                        error: None,
                    });
                }
            }
            let mut batch = drained.run;
            if batch.is_empty() {
                continue;
            }
            let drained_at = Instant::now();
            let batch_us = self.now_us();
            let task = batch[0].req.task;
            if self.cfg.obs.armed() {
                for p in &batch {
                    self.cfg.obs.event_at(
                        batch_us,
                        EventCode::BatchFormed,
                        p.req.id,
                        task as u64,
                    );
                }
            }
            for p in &mut batch {
                p.batch_us = batch_us;
            }
            let folded = self.store.get(task);
            // Queue-delay telemetry is computed here but committed only on
            // success — a supervised failure requeues the batch, and its
            // eventual successful drain must be the one that counts.
            let mut queue_us = 0u64;
            let mut queue_us_max = 0u64;
            for (i, p) in batch.iter().enumerate() {
                tokens[i * s..(i + 1) * s].copy_from_slice(&p.req.tokens);
                let waited = drained_at.saturating_duration_since(p.enqueued);
                let us = waited.as_micros() as u64;
                queue_us += us;
                queue_us_max = queue_us_max.max(us);
            }
            // Pad short batches by repeating row 0 (valid tokens; output
            // rows beyond the real requests are simply never read).
            for i in batch.len()..b {
                let (head, tail) = tokens.split_at_mut(i * s);
                tail[..s].copy_from_slice(&head[..s]);
            }
            // Supervision guard: a panic (injected or real) or an execution
            // error inside the forward must not take down the engine. On
            // failure the batch is requeued (twice-failed requests retried
            // solo, thrice-failed quarantined) and THIS worker re-binds a
            // fresh step — its workspace may be mid-tick garbage after an
            // unwind. `AssertUnwindSafe` is sound here precisely because
            // the potentially-broken state (step, logits) is rebuilt /
            // fully overwritten before reuse.
            // Tick-start is stamped BEFORE the fault hook so an injected
            // slow tick is inside the tick span (and the compute stage) —
            // `slow_tick=<D>ms@p=1.0` provably yields tick spans ≥ D.
            let start_us = self.now_us();
            self.cfg.obs.event_at(
                start_us,
                EventCode::TickStart,
                task as u64,
                batch.len() as u64,
            );
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let slept_us = self.cfg.faults.on_serve_tick();
                step.run_serve_packed(&folded.pairs, &tokens, task as i32, &mut logits)
                    .map(|()| slept_us)
            }));
            let end_us = self.now_us();
            let (why, slept_us) = match run {
                Ok(Ok(slept_us)) => (None, slept_us),
                Ok(Err(e)) => (Some(format!("batch execution failed: {e:#}")), 0),
                Err(_) => (Some("worker panicked executing a batch".to_string()), 0),
            };
            if let Some(why) = why {
                self.supervise_failed_batch(batch, &why);
                step = self.backend.bind_serve(&self.spec, &self.frozen, self.cfg.dtype)?;
                continue;
            }
            self.stats.queue_us_sum.fetch_add(queue_us, Ordering::Relaxed);
            self.stats.queue_us_max.fetch_max(queue_us_max, Ordering::Relaxed);
            self.stats.batches.fetch_add(1, Ordering::Relaxed);
            self.stats.requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
            self.stats.hist.lock().unwrap()[batch.len()] += 1;
            let rows = batch.len();
            let done_us = self.now_us();
            // One armed check covers the whole tick's worth of span +
            // histogram traffic; unarmed, this entire block is one load.
            if self.cfg.obs.armed() {
                let obs = &self.cfg.obs;
                obs.event_at(end_us, EventCode::TickEnd, task as u64, start_us);
                if slept_us > 0 {
                    obs.event_at(start_us, EventCode::SlowTick, slept_us, task as u64);
                }
                obs.stages.tick_us.observe(end_us.saturating_sub(start_us));
                if let Some(c) = self.task_requests.get(task) {
                    c.add(rows as u64);
                }
                for p in &batch {
                    obs.event_at(done_us, EventCode::ResponseWritten, p.req.id, task as u64);
                    let stamps = StageStamps {
                        admit_us: p.admit_us,
                        batch_us: p.batch_us,
                        start_us,
                        end_us,
                    };
                    obs.stages.queue_wait_us.observe(stamps.queue_wait_us());
                    obs.stages.batch_wait_us.observe(stamps.batch_wait_us());
                    obs.stages.compute_us.observe(stamps.compute_us());
                    obs.stages.respond_us.observe(stamps.respond_us(done_us));
                }
            }
            for (i, p) in batch.into_iter().enumerate() {
                // A dropped receiver (client gave up) is not an engine
                // error; ignore the send result.
                let _ = p.tx.send(Response {
                    id: p.req.id,
                    task,
                    status: ResponseStatus::Ok,
                    logits: logits[i * classes..(i + 1) * classes].to_vec(),
                    batch_rows: rows,
                    generation: folded.generation,
                    done_us,
                    stamps: StageStamps {
                        admit_us: p.admit_us,
                        batch_us: p.batch_us,
                        start_us,
                        end_us,
                    },
                    error: None,
                });
            }
        }
        Ok(())
    }

    /// Self-healing after a failed batch execution: every member's failure
    /// count rises; a request that has now failed twice goes back flagged
    /// `solo` (retried in a batch of one), and a request that failed *as*
    /// that batch-of-one is poisoned — it gets an explicit `Error` response
    /// so its former batch-mates (already requeued separately) can succeed
    /// without it. Requeued requests keep their original deadlines: one
    /// that expires while retrying is still answered (`Expired`), never
    /// silently dropped.
    fn supervise_failed_batch(&self, batch: Vec<Pending>, why: &str) {
        let restarts = self.stats.worker_restarts.fetch_add(1, Ordering::Relaxed) + 1;
        let single = batch.len() == 1;
        let done_us = self.now_us();
        let task = batch.first().map(|p| p.req.task as u64).unwrap_or(0);
        self.cfg.obs.event_at(done_us, EventCode::WorkerRestart, task, restarts);
        let mut requeue = Vec::with_capacity(batch.len());
        for mut p in batch {
            p.panics = p.panics.saturating_add(1);
            if single && p.panics >= 2 {
                self.stats.quarantined.fetch_add(1, Ordering::Relaxed);
                self.cfg.obs.event_at(
                    done_us,
                    EventCode::Quarantine,
                    p.req.id,
                    p.req.task as u64,
                );
                let _ = p.tx.send(Response {
                    id: p.req.id,
                    task: p.req.task,
                    status: ResponseStatus::Error,
                    logits: Vec::new(),
                    batch_rows: 0,
                    generation: 0,
                    done_us,
                    stamps: StageStamps { admit_us: p.admit_us, ..StageStamps::default() },
                    error: Some(format!(
                        "request quarantined after {} failed executions ({why})",
                        p.panics
                    )),
                });
            } else {
                p.solo = p.panics >= 2;
                requeue.push(p);
            }
        }
        self.stats.requeued.fetch_add(requeue.len() as u64, Ordering::Relaxed);
        self.cfg.obs.event_at(done_us, EventCode::Requeue, task, requeue.len() as u64);
        self.queue.requeue(requeue);
    }
}

/// Anything the serving front-ends can sit on: a single [`ServingEngine`]
/// or an N-shard [`super::router::ShardRouter`]. The TCP front-end
/// (`serve_net`) and the load generators are generic over this seam, which
/// is what keeps the MTS1 wire protocol and the admission semantics
/// identical whether requests land on one engine or are routed across a
/// topology — routing happens strictly *behind* admission.
pub trait ServeTarget: Sync {
    /// Sequence length every request must be tokenized to.
    fn seq_len(&self) -> usize;
    /// Vocabulary bound for request token ids.
    fn vocab(&self) -> usize;
    /// Classes per task head (the logits row width).
    fn classes(&self) -> usize;
    /// Number of served tasks.
    fn num_tasks(&self) -> usize;
    /// Total worker threads across the target (warmup sizing).
    fn workers(&self) -> usize;
    /// Microseconds on the target's response-stamp clock.
    fn now_us(&self) -> u64;
    /// The fault-injection plan threaded into front-end hooks.
    fn faults(&self) -> &FaultPlan;
    /// The observability handle (span tracer + metrics registry + protocol
    /// error counters) shared across the target.
    fn obs(&self) -> &Arc<Obs>;
    /// Folded-adapter cache counters, aggregated across shards for a router.
    fn cache_stats(&self) -> CacheStats;
    /// Prometheus-style text snapshot of every metric family the target
    /// produces — what the MTS1 `STAT` admin frame and `--metrics-out`
    /// serve from a live engine or topology.
    fn metrics_text(&self) -> String;
    /// Current adapter-store generation (max across shards for a router).
    fn generation(&self) -> u64;
    /// Blocking admission with deadline + priority class.
    fn submit_with(
        &self,
        task: usize,
        tokens: Vec<i32>,
        deadline: Option<Duration>,
        priority: u8,
    ) -> Result<ResponseHandle>;
    /// Non-blocking admission for open-loop load (`Ok(None)` = rejected).
    fn try_submit_with(
        &self,
        task: usize,
        tokens: Vec<i32>,
        deadline: Option<Duration>,
        priority: u8,
    ) -> Result<Option<ResponseHandle>>;
    /// Execution counters, aggregated across shards for a router.
    fn stats(&self) -> EngineStats;
    /// Spawn the worker pool(s), run `driver`, then drain and join —
    /// the same graceful-shutdown contract as [`ServingEngine::serve`].
    fn serve_session<R>(&self, driver: impl FnOnce(&Self) -> R) -> Result<R>
    where
        Self: Sized;
}

impl ServeTarget for ServingEngine<'_> {
    fn seq_len(&self) -> usize {
        ServingEngine::seq_len(self)
    }
    fn vocab(&self) -> usize {
        ServingEngine::vocab(self)
    }
    fn classes(&self) -> usize {
        self.cfg.classes
    }
    fn num_tasks(&self) -> usize {
        self.cfg.num_tasks
    }
    fn workers(&self) -> usize {
        self.cfg.workers
    }
    fn now_us(&self) -> u64 {
        ServingEngine::now_us(self)
    }
    fn faults(&self) -> &FaultPlan {
        ServingEngine::faults(self)
    }
    fn obs(&self) -> &Arc<Obs> {
        ServingEngine::obs(self)
    }
    fn cache_stats(&self) -> CacheStats {
        ServingEngine::cache_stats(self)
    }
    fn metrics_text(&self) -> String {
        ServingEngine::metrics_text(self)
    }
    fn generation(&self) -> u64 {
        ServingEngine::generation(self)
    }
    fn submit_with(
        &self,
        task: usize,
        tokens: Vec<i32>,
        deadline: Option<Duration>,
        priority: u8,
    ) -> Result<ResponseHandle> {
        ServingEngine::submit_with(self, task, tokens, deadline, priority)
    }
    fn try_submit_with(
        &self,
        task: usize,
        tokens: Vec<i32>,
        deadline: Option<Duration>,
        priority: u8,
    ) -> Result<Option<ResponseHandle>> {
        ServingEngine::try_submit_with(self, task, tokens, deadline, priority)
    }
    fn stats(&self) -> EngineStats {
        ServingEngine::stats(self)
    }
    fn serve_session<R>(&self, driver: impl FnOnce(&Self) -> R) -> Result<R> {
        ServingEngine::serve(self, driver)
    }
}

/// Render the engine-side metric families (the `EngineStats` producer) in
/// Prometheus text format. Shared by the engine and the shard router (which
/// feeds aggregated stats plus its own shard-health families).
pub(crate) fn render_engine_families(
    out: &mut String,
    stats: &EngineStats,
    cache: &CacheStats,
    generation: u64,
    queue_depth: usize,
) {
    use std::fmt::Write;
    let counters = [
        ("metatt_engine_batches_total", stats.batches),
        ("metatt_engine_requests_total", stats.requests),
        ("metatt_engine_shed_total", stats.shed),
        ("metatt_engine_rejected_total", stats.rejected),
        ("metatt_engine_worker_restarts_total", stats.worker_restarts),
        ("metatt_engine_quarantined_total", stats.quarantined),
        ("metatt_engine_requeued_total", stats.requeued),
        ("metatt_engine_queue_us_sum", stats.queue_us_sum),
        ("metatt_cache_hits_total", cache.hits),
        ("metatt_cache_folds_total", cache.folds),
        ("metatt_cache_evictions_total", cache.evictions),
        ("metatt_cache_reloads_total", cache.reloads),
    ];
    for (name, v) in counters {
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {v}");
    }
    let gauges = [
        ("metatt_engine_queue_us_max", stats.queue_us_max),
        ("metatt_engine_queue_depth", queue_depth as u64),
        ("metatt_cache_bytes", cache.bytes),
        ("metatt_generation", generation),
    ];
    for (name, v) in gauges {
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {v}");
    }
    let _ = writeln!(out, "# TYPE metatt_engine_batch_size_total counter");
    for (size, &n) in stats.batch_hist.iter().enumerate().skip(1) {
        if n > 0 {
            let _ = writeln!(out, "metatt_engine_batch_size_total{{size=\"{size}\"}} {n}");
        }
    }
}

/// Build an [`AdapterSpec`] matching an engine config (shared by the CLI
/// and tests when constructing or checkpointing adapters for serving).
pub fn adapter_spec_for(cfg: &EngineConfig) -> AdapterSpec {
    AdapterSpec::new(cfg.adapter, cfg.rank, cfg.alpha, cfg.model.dims(cfg.num_tasks))
}

/// Reject an adapter state that cannot serve this config. The task arity
/// is structural only for the (4+1)D task core — a task-free 4D/5D adapter
/// may serve any number of per-task heads.
fn validate_adapter_fit(
    kind: crate::tt::MetaTtKind,
    cfg: &EngineConfig,
    tt: &MetaTt,
) -> Result<()> {
    let want = MetaTt::dims_from_model(kind, &cfg.model.dims(cfg.num_tasks));
    let mut got = tt.dims;
    if kind != crate::tt::MetaTtKind::FourPlusOneD {
        got.tasks = want.tasks;
    }
    if tt.kind != kind || got != want {
        bail!(
            "adapter state does not fit the serving config: state is {:?} over \
             {:?}, config wants {:?} over {:?}",
            tt.kind,
            tt.dims,
            kind,
            want
        );
    }
    Ok(())
}
