//! The multi-task serving engine: admission queue → dynamic batcher →
//! per-task folded-adapter cache → worker execution on the ref backend.
//!
//! One engine binds a single eval-spec step layout (batch = `max_batch`)
//! against the frozen backbone and serves T tasks through it. Each worker
//! thread binds its **own** step, so warmed serving ticks run concurrently
//! on private workspace arenas (zero heap allocations per tick, pinned by
//! `tests/alloc_regression.rs`) while the thread budget *inside* a tick is
//! the backend's `--threads` kernel banding.
//!
//! Short batches are padded by repeating the first request's row; padding
//! rows are computed and discarded. Every row of the batch depends only on
//! its own tokens, so a response's bits are independent of batch
//! composition — 1-worker and N-worker engines answer a given request
//! stream bit-identically (`tests/serving.rs`).

use super::batcher::BatchPolicy;
use super::cache::{AdapterStore, CacheStats};
use super::request::{
    response_channel, AdmissionQueue, Pending, Request, Response, ResponseHandle,
};
use crate::adapters::{AdapterKind, AdapterSpec};
use crate::config::ModelPreset;
use crate::runtime::{assemble_frozen, ArtifactSpec, Backend, StepKind};
use crate::tensor::Tensor;
use crate::tt::MetaTt;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Engine configuration (CLI flags map 1:1 onto these).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub model: ModelPreset,
    /// Adapter family (must be a MetaTT variant — folding is the TT story).
    pub adapter: AdapterKind,
    pub rank: usize,
    pub alpha: f32,
    /// Number of served tasks (classifier-head arity; task-core arity for
    /// the (4+1)D family).
    pub num_tasks: usize,
    /// Classes per task head (synthetic GLUE-style tasks are binary).
    pub classes: usize,
    /// Dynamic-batch cap = the bound eval spec's batch dimension.
    pub max_batch: usize,
    /// How long a short batch waits for same-task stragglers.
    pub batch_deadline: Duration,
    /// Admission-queue bound (producers block beyond it).
    pub queue_capacity: usize,
    /// Worker threads executing batches (each binds its own step).
    pub workers: usize,
    /// Folded-adapter LRU capacity (entries per generation).
    pub cache_capacity: usize,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig {
            model: ModelPreset::Tiny,
            adapter: AdapterKind::MetaTt(crate::tt::MetaTtKind::FourPlusOneD),
            rank: 8,
            alpha: 2.0,
            num_tasks: 3,
            classes: 2,
            max_batch: 8,
            batch_deadline: Duration::from_millis(2),
            queue_capacity: 256,
            workers: 2,
            cache_capacity: 8,
        }
    }
}

/// Execution counters, all monotone (read with [`ServingEngine::stats`]).
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub batches: u64,
    pub requests: u64,
    /// `hist[k]` = batches that carried exactly k real requests (index 0
    /// unused).
    pub batch_hist: Vec<u64>,
}

struct StatsInner {
    batches: AtomicU64,
    requests: AtomicU64,
    hist: Mutex<Vec<u64>>,
}

/// The engine. Holds no worker threads itself — [`ServingEngine::serve`]
/// scopes them around a caller-supplied driver closure, so the engine can
/// borrow the backend and still be used from plain tests and the CLI.
pub struct ServingEngine<'b> {
    backend: &'b dyn Backend,
    cfg: EngineConfig,
    spec: ArtifactSpec,
    seq: usize,
    vocab: usize,
    frozen: Arc<HashMap<String, Tensor>>,
    store: AdapterStore,
    queue: AdmissionQueue,
    policy: BatchPolicy,
    stats: StatsInner,
    next_id: AtomicU64,
}

impl<'b> ServingEngine<'b> {
    /// Build an engine over `backend`, serving `tt` (chain form, typically
    /// rebuilt from a checkpoint via
    /// [`super::cache::metatt_from_tensors`]). `backbone` points at a
    /// pretrained-backbone checkpoint; None falls back to the seeded
    /// deterministic backbone (same rule as training).
    pub fn new(
        backend: &'b dyn Backend,
        cfg: EngineConfig,
        tt: MetaTt,
        backbone: Option<&Path>,
    ) -> Result<ServingEngine<'b>> {
        if cfg.max_batch < 1 || cfg.workers < 1 || cfg.num_tasks < 1 || cfg.classes < 1 {
            bail!("serving config: max_batch, workers, num_tasks, classes must all be >= 1");
        }
        if cfg.queue_capacity < 1 || cfg.cache_capacity < 1 {
            bail!("serving config: queue_capacity and cache_capacity must be >= 1");
        }
        let AdapterKind::MetaTt(kind) = cfg.adapter else {
            bail!(
                "serving folds TT adapters only (got '{}'); train MetaTT variants \
                 for multi-task serving",
                cfg.adapter.name()
            );
        };
        let dims = cfg.model.dims(cfg.num_tasks);
        validate_adapter_fit(kind, &cfg, &tt)?;
        let spec = ArtifactSpec {
            step: StepKind::Eval,
            model: cfg.model.name().to_string(),
            adapter: cfg.adapter.name(),
            rank: cfg.rank,
            classes: cfg.classes,
            tasks: cfg.num_tasks,
            batch: cfg.max_batch,
            seq: dims.max_seq,
        };
        let entry = backend.entry(&spec)?;
        let frozen = Arc::new(assemble_frozen(&entry, backbone, cfg.model)?);
        let store = AdapterStore::new(tt, cfg.cache_capacity);
        let queue = AdmissionQueue::new(cfg.queue_capacity);
        let policy = BatchPolicy { max_batch: cfg.max_batch, deadline: cfg.batch_deadline };
        let hist = vec![0u64; cfg.max_batch + 1];
        Ok(ServingEngine {
            backend,
            cfg,
            spec,
            seq: dims.max_seq,
            vocab: dims.vocab,
            frozen,
            store,
            queue,
            policy,
            stats: StatsInner {
                batches: AtomicU64::new(0),
                requests: AtomicU64::new(0),
                hist: Mutex::new(hist),
            },
            next_id: AtomicU64::new(0),
        })
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Sequence length every request must be tokenized to.
    pub fn seq_len(&self) -> usize {
        self.seq
    }

    /// Vocabulary bound for request token ids.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Current adapter-store generation (bumped by [`Self::reload`]).
    pub fn generation(&self) -> u64 {
        self.store.generation()
    }

    /// Folded-adapter cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.store.stats()
    }

    /// Execution counters (batch-size histogram index = real requests).
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            batches: self.stats.batches.load(Ordering::Relaxed),
            requests: self.stats.requests.load(Ordering::Relaxed),
            batch_hist: self.stats.hist.lock().unwrap().clone(),
        }
    }

    /// Hot-swap the adapter to a new chain state (e.g. a freshly-loaded
    /// checkpoint) without draining in-flight batches: they finish on the
    /// generation they snapshotted; subsequent batches fold from the new
    /// one.
    pub fn reload(&self, tt: MetaTt) -> Result<()> {
        let AdapterKind::MetaTt(kind) = self.cfg.adapter else {
            unreachable!("constructor enforces a MetaTT adapter");
        };
        validate_adapter_fit(kind, &self.cfg, &tt)?;
        self.store.reload(tt);
        Ok(())
    }

    /// Admit one request (blocking while the queue is full). The returned
    /// handle resolves to the [`Response`] once a worker's batch carried it.
    pub fn submit(&self, task: usize, tokens: Vec<i32>) -> Result<ResponseHandle> {
        if task >= self.cfg.num_tasks {
            bail!("task {task} out of range ({} served)", self.cfg.num_tasks);
        }
        if tokens.len() != self.seq {
            bail!("request has {} tokens, spec wants {}", tokens.len(), self.seq);
        }
        if let Some(&t) = tokens.iter().find(|&&t| t < 0 || t as usize >= self.vocab) {
            bail!("token id {t} outside [0, {})", self.vocab);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = response_channel();
        self.queue
            .submit(Pending {
                req: Request { id, task, tokens },
                tx,
                enqueued: Instant::now(),
            })
            .map_err(|e| anyhow!(e))?;
        Ok(ResponseHandle { id, rx })
    }

    /// Run the engine: spawn the worker pool, hand control to `driver`
    /// (submit requests, reload checkpoints, …), then close the queue,
    /// drain, and join. Worker failures — errors *or* panics — surface as
    /// the returned error; a failing worker aborts the queue (close +
    /// drop every queued request), so clients blocked on handles observe
    /// a receive error instead of hanging and blocked producers wake up.
    pub fn serve<R>(&self, driver: impl FnOnce(&Self) -> R) -> Result<R> {
        std::thread::scope(|scope| {
            let workers: Vec<_> = (0..self.cfg.workers)
                .map(|_| {
                    scope.spawn(|| {
                        // catch_unwind so a panicking worker still runs the
                        // fail-fast abort (a poisoned unwrap must not leave
                        // admitted requests waiting on no one).
                        let res = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| self.worker_loop()),
                        );
                        match res {
                            Ok(Ok(())) => Ok(()),
                            Ok(Err(e)) => {
                                self.queue.abort();
                                Err(e)
                            }
                            Err(_) => {
                                self.queue.abort();
                                Err(anyhow!("a serving worker panicked"))
                            }
                        }
                    })
                })
                .collect();
            // The driver is unwind-guarded too: a panicking driver (e.g. a
            // failing test assertion) must still close the queue, or the
            // scope would block forever joining workers parked on it. The
            // panic is re-raised after the pool has shut down.
            let out =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| driver(self)));
            self.queue.close();
            let mut first_err = None;
            for w in workers {
                match w.join() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => first_err = first_err.or(Some(e)),
                    Err(_) => {
                        first_err =
                            first_err.or(Some(anyhow!("a serving worker panicked")));
                    }
                }
            }
            let out = match out {
                Ok(v) => v,
                Err(payload) => std::panic::resume_unwind(payload),
            };
            match first_err {
                Some(e) => Err(e),
                None => Ok(out),
            }
        })
    }

    /// One worker: bind a private step, then batch → fold-lookup → execute
    /// → fulfil until the queue closes. The token and logit buffers are
    /// reused across ticks, so a warmed tick's only allocations are the
    /// per-response logit vectors handed to clients.
    fn worker_loop(&self) -> Result<()> {
        let step = self.backend.bind(&self.spec, &self.frozen)?;
        let (b, s, classes) = (self.cfg.max_batch, self.seq, self.cfg.classes);
        let mut tokens = vec![0i32; b * s];
        let mut logits = vec![0f32; b * classes];
        while let Some(batch) = self.policy.next_batch(&self.queue) {
            let task = batch[0].req.task;
            let folded = self.store.get(task);
            for (i, p) in batch.iter().enumerate() {
                tokens[i * s..(i + 1) * s].copy_from_slice(&p.req.tokens);
            }
            // Pad short batches by repeating row 0 (valid tokens; output
            // rows beyond the real requests are simply never read).
            for i in batch.len()..b {
                let (head, tail) = tokens.split_at_mut(i * s);
                tail[..s].copy_from_slice(&head[..s]);
            }
            step.run_serve(&folded.pairs, &tokens, task as i32, &mut logits)?;
            self.stats.batches.fetch_add(1, Ordering::Relaxed);
            self.stats.requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
            self.stats.hist.lock().unwrap()[batch.len()] += 1;
            let rows = batch.len();
            for (i, p) in batch.into_iter().enumerate() {
                // A dropped receiver (client gave up) is not an engine
                // error; ignore the send result.
                let _ = p.tx.send(Response {
                    id: p.req.id,
                    task,
                    logits: logits[i * classes..(i + 1) * classes].to_vec(),
                    batch_rows: rows,
                    generation: folded.generation,
                });
            }
        }
        Ok(())
    }
}

/// Build an [`AdapterSpec`] matching an engine config (shared by the CLI
/// and tests when constructing or checkpointing adapters for serving).
pub fn adapter_spec_for(cfg: &EngineConfig) -> AdapterSpec {
    AdapterSpec::new(cfg.adapter, cfg.rank, cfg.alpha, cfg.model.dims(cfg.num_tasks))
}

/// Reject an adapter state that cannot serve this config. The task arity
/// is structural only for the (4+1)D task core — a task-free 4D/5D adapter
/// may serve any number of per-task heads.
fn validate_adapter_fit(
    kind: crate::tt::MetaTtKind,
    cfg: &EngineConfig,
    tt: &MetaTt,
) -> Result<()> {
    let want = MetaTt::dims_from_model(kind, &cfg.model.dims(cfg.num_tasks));
    let mut got = tt.dims;
    if kind != crate::tt::MetaTtKind::FourPlusOneD {
        got.tasks = want.tasks;
    }
    if tt.kind != kind || got != want {
        bail!(
            "adapter state does not fit the serving config: state is {:?} over \
             {:?}, config wants {:?} over {:?}",
            tt.kind,
            tt.dims,
            kind,
            want
        );
    }
    Ok(())
}
