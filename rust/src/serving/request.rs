//! Request/response types and the bounded admission queue.
//!
//! The queue is the engine's front door: [`AdmissionQueue::submit`] blocks
//! (bounded backpressure) until a slot frees up or the engine shuts down,
//! and workers drain it through the dynamic batcher
//! ([`crate::serving::BatchPolicy`]). Each submission carries a one-shot
//! response channel, so fulfilment never goes back through a shared lock.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// One classification request: a task index and one example's token ids
/// (exactly the spec's sequence length, pre-tokenized).
#[derive(Clone, Debug)]
pub struct Request {
    /// Engine-assigned id (unique per engine instance).
    pub id: u64,
    /// Task index (selects the folded adapter slice and the frozen head).
    pub task: usize,
    /// Token ids, length = spec seq, each in `[0, vocab)`.
    pub tokens: Vec<i32>,
}

/// The engine's answer to one [`Request`].
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub task: usize,
    /// Per-class logits through the task's frozen head.
    pub logits: Vec<f32>,
    /// How many real requests shared this request's batch (telemetry; the
    /// logits bits are independent of it).
    pub batch_rows: usize,
    /// Adapter-store generation the folded factors came from.
    pub generation: u64,
}

/// A queued request plus its completion channel and admission timestamp.
pub(crate) struct Pending {
    pub req: Request,
    pub tx: mpsc::Sender<Response>,
    #[allow(dead_code)] // queue-delay telemetry hook; latency is client-side
    pub enqueued: Instant,
}

/// Client-side handle to one in-flight request.
pub struct ResponseHandle {
    pub id: u64,
    pub(crate) rx: mpsc::Receiver<Response>,
}

impl ResponseHandle {
    /// Block until the response arrives. Errors if the engine dropped the
    /// request (worker failure / shutdown before execution).
    pub fn wait(self) -> Result<Response, String> {
        self.rx
            .recv()
            .map_err(|_| format!("request {} dropped before a response was produced", self.id))
    }
}

pub(crate) struct QueueInner {
    pub queue: VecDeque<Pending>,
    pub closed: bool,
}

/// Bounded MPMC admission queue: producers block when full, workers block
/// when empty, `close` wakes everyone for shutdown (already-admitted
/// requests still drain).
pub struct AdmissionQueue {
    pub(crate) inner: Mutex<QueueInner>,
    pub(crate) not_empty: Condvar,
    pub(crate) not_full: Condvar,
    capacity: usize,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> AdmissionQueue {
        assert!(capacity >= 1, "admission queue capacity must be >= 1");
        AdmissionQueue {
            inner: Mutex::new(QueueInner { queue: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Admit a request, blocking while the queue is at capacity. Errors
    /// once the queue is closed.
    pub(crate) fn submit(&self, p: Pending) -> Result<(), String> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.closed {
                return Err("serving engine is shut down".into());
            }
            if inner.queue.len() < self.capacity {
                inner.queue.push_back(p);
                // Batching workers may all be parked in deadline waits on
                // `not_empty`; wake every one so the first-request waiter
                // is never starved by a filler.
                self.not_empty.notify_all();
                return Ok(());
            }
            inner = self.not_full.wait(inner).unwrap();
        }
    }

    /// Close the queue: new submissions fail, workers drain what's left
    /// and then observe the closed flag.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Fail-fast close: close AND drop every queued request. Dropping a
    /// `Pending` drops its response sender, so blocked clients observe a
    /// receive error instead of hanging forever — this is the worker-failure
    /// path, where nothing may remain that no one will ever execute.
    pub fn abort(&self) {
        let drained = {
            let mut inner = self.inner.lock().unwrap();
            inner.closed = true;
            self.not_empty.notify_all();
            self.not_full.notify_all();
            std::mem::take(&mut inner.queue)
        };
        // Senders drop outside the lock.
        drop(drained);
    }

    /// Requests currently waiting (diagnostics).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One-shot completion channel for a request (engine + tests).
pub(crate) fn response_channel() -> (mpsc::Sender<Response>, mpsc::Receiver<Response>) {
    mpsc::channel()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(id: u64, task: usize) -> (Pending, mpsc::Receiver<Response>) {
        let (tx, rx) = response_channel();
        (
            Pending {
                req: Request { id, task, tokens: vec![1, 2, 3] },
                tx,
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn submit_and_close_semantics() {
        let q = AdmissionQueue::new(2);
        let (p0, _rx0) = pending(0, 0);
        let (p1, _rx1) = pending(1, 1);
        q.submit(p0).unwrap();
        q.submit(p1).unwrap();
        assert_eq!(q.len(), 2);
        q.close();
        let (p2, _rx2) = pending(2, 0);
        assert!(q.submit(p2).is_err(), "closed queue must reject submissions");
        // Already-admitted requests are still visible for draining.
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn bounded_capacity_blocks_until_a_worker_drains() {
        use std::sync::Arc;
        let q = Arc::new(AdmissionQueue::new(1));
        let (p0, _rx0) = pending(0, 0);
        q.submit(p0).unwrap();
        // A second submit must block until the queue has room.
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let (p1, rx1) = pending(1, 0);
            q2.submit(p1).map(|_| rx1)
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.len(), 1, "second submit should still be parked");
        // Drain one; the parked producer gets its slot.
        {
            let mut inner = q.inner.lock().unwrap();
            let _ = inner.queue.pop_front();
            q.not_full.notify_all();
        }
        h.join().unwrap().unwrap();
        assert_eq!(q.len(), 1);
    }
}
