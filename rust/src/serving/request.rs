//! Request/response types and the bounded admission queue.
//!
//! The queue is the engine's front door: [`AdmissionQueue::submit`] blocks
//! (bounded backpressure) until a slot frees up or the engine shuts down,
//! and workers drain it through the dynamic batcher
//! ([`crate::serving::BatchPolicy`]). Each submission carries a one-shot
//! response channel, so fulfilment never goes back through a shared lock.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// One classification request: a task index and one example's token ids
/// (exactly the spec's sequence length, pre-tokenized).
#[derive(Clone, Debug)]
pub struct Request {
    /// Engine-assigned id (unique AND admission-ordered per engine
    /// instance — the batcher's urgency tiebreak relies on monotonicity).
    pub id: u64,
    /// Task index (selects the folded adapter slice and the frozen head).
    pub task: usize,
    /// Token ids, length = spec seq, each in `[0, vocab)`.
    pub tokens: Vec<i32>,
    /// Scheduling class: **lower value = more urgent** (nice-style). The
    /// batcher orders by (priority, deadline, admission) — strict priority,
    /// so a saturating high-priority stream can starve lower classes; that
    /// is the intended overload contract (low classes shed via deadlines).
    pub priority: u8,
}

/// How the engine answered a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResponseStatus {
    /// Computed: `logits` carry the per-class scores.
    Ok,
    /// Shed: the deadline had already passed when a worker reached the
    /// request, so no compute was spent; `logits` is empty.
    Expired,
    /// Quarantined: the request repeatedly failed execution (poisoned —
    /// its batch panicked, it was retried solo, and it panicked again).
    /// `logits` is empty; `error` names the failure. Its batch-mates are
    /// unaffected.
    Error,
}

/// Per-request stage timeline, µs on the engine's `done_us` clock (PR 10).
/// Stamped unconditionally — four clock reads per *batch* plus one copy per
/// request — so serve reports can break latency into stages even with
/// tracing unarmed. Zero-filled (except `admit_us`) on responses that never
/// reached compute (shed / quarantine / failover synthesized).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageStamps {
    /// Admission into the queue.
    pub admit_us: u64,
    /// Drained into a batch by a worker.
    pub batch_us: u64,
    /// Serve tick started (before fault injection, so injected slow ticks
    /// are visible in the compute stage and in tick spans).
    pub start_us: u64,
    /// Serve tick finished.
    pub end_us: u64,
}

impl StageStamps {
    /// queue-wait: admission → batch-formed.
    pub fn queue_wait_us(&self) -> u64 {
        self.batch_us.saturating_sub(self.admit_us)
    }

    /// batch-wait: batch-formed → tick-start (padding, fold lookup).
    pub fn batch_wait_us(&self) -> u64 {
        self.start_us.saturating_sub(self.batch_us)
    }

    /// compute: tick-start → tick-end.
    pub fn compute_us(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }

    /// respond-write: tick-end → `done_us` (response fan-out).
    pub fn respond_us(&self, done_us: u64) -> u64 {
        done_us.saturating_sub(self.end_us)
    }

    /// Whether this response went through a real serve tick (stage
    /// breakdowns only aggregate these).
    pub fn complete(&self) -> bool {
        self.admit_us <= self.batch_us && self.batch_us <= self.start_us && self.start_us > 0
    }
}

/// The engine's answer to one [`Request`].
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub task: usize,
    pub status: ResponseStatus,
    /// Per-class logits through the task's frozen head (empty when shed).
    pub logits: Vec<f32>,
    /// How many real requests shared this request's batch (telemetry; the
    /// logits bits are independent of it). 0 when shed.
    pub batch_rows: usize,
    /// Adapter-store generation the folded factors came from (0 when shed —
    /// no factors were looked up).
    pub generation: u64,
    /// Microseconds since engine start when this response was produced.
    /// Lets open-loop load generation measure completion-time latency and
    /// deadline attainment without a collector thread in the timing path.
    pub done_us: u64,
    /// Stage timeline (admit / batch-formed / tick-start / tick-end) on the
    /// same clock as `done_us`; see [`StageStamps`].
    pub stamps: StageStamps,
    /// Failure description when `status` is [`ResponseStatus::Error`];
    /// `None` otherwise.
    pub error: Option<String>,
}

/// A queued request plus its completion channel, admission timestamp, and
/// absolute deadline (admission time + the client's relative deadline).
pub(crate) struct Pending {
    pub req: Request,
    pub tx: mpsc::Sender<Response>,
    /// Admission timestamp — queue-delay telemetry (`EngineStats`) and the
    /// base the absolute deadline was derived from.
    pub enqueued: Instant,
    /// Absolute expiry: a worker that reaches this request at or after the
    /// deadline sheds it instead of computing dead work. None = never.
    pub deadline: Option<Instant>,
    /// Admission stamp on the engine's `done_us` clock (µs) — seeds the
    /// response's [`StageStamps`].
    pub admit_us: u64,
    /// Stamped by the draining worker when this request joins a batch.
    pub batch_us: u64,
    /// How many times a batch containing this request failed (panic or
    /// execution error). Supervision increments it on requeue; at 2 the
    /// request runs solo, and a solo failure quarantines it.
    pub panics: u32,
    /// Quarantine-retry flag: run this request in a batch of one so a
    /// poisoned batch-mate can't take it down (and vice versa).
    pub solo: bool,
}

impl Pending {
    pub(crate) fn expired_at(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// Scheduling order: priority class first (lower = more urgent), then
    /// earliest deadline (deadline-free requests sort after any deadline),
    /// then admission order (ids are monotone).
    pub(crate) fn cmp_urgency(&self, other: &Pending) -> std::cmp::Ordering {
        use std::cmp::Ordering;
        self.req
            .priority
            .cmp(&other.req.priority)
            .then_with(|| match (self.deadline, other.deadline) {
                (Some(a), Some(b)) => a.cmp(&b),
                (Some(_), None) => Ordering::Less,
                (None, Some(_)) => Ordering::Greater,
                (None, None) => Ordering::Equal,
            })
            .then_with(|| self.req.id.cmp(&other.req.id))
    }
}

/// Client-side handle to one in-flight request.
pub struct ResponseHandle {
    pub id: u64,
    pub(crate) rx: mpsc::Receiver<Response>,
}

impl ResponseHandle {
    /// Block until the response arrives. Errors if the engine dropped the
    /// request (worker failure / shutdown before execution).
    pub fn wait(self) -> Result<Response, String> {
        self.rx
            .recv()
            .map_err(|_| format!("request {} dropped before a response was produced", self.id))
    }
}

pub(crate) struct QueueInner {
    pub queue: VecDeque<Pending>,
    pub closed: bool,
}

/// Bounded MPMC admission queue: producers block when full, workers block
/// when empty, `close` wakes everyone for shutdown (already-admitted
/// requests still drain).
pub struct AdmissionQueue {
    pub(crate) inner: Mutex<QueueInner>,
    pub(crate) not_empty: Condvar,
    pub(crate) not_full: Condvar,
    capacity: usize,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> AdmissionQueue {
        assert!(capacity >= 1, "admission queue capacity must be >= 1");
        AdmissionQueue {
            inner: Mutex::new(QueueInner { queue: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Admit a request, blocking while the queue is at capacity. Errors
    /// once the queue is closed.
    pub(crate) fn submit(&self, p: Pending) -> Result<(), String> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.closed {
                return Err("serving engine is shut down".into());
            }
            if inner.queue.len() < self.capacity {
                inner.queue.push_back(p);
                // Batching workers may all be parked in deadline waits on
                // `not_empty`; wake every one so the first-request waiter
                // is never starved by a filler.
                self.not_empty.notify_all();
                return Ok(());
            }
            inner = self.not_full.wait(inner).unwrap();
        }
    }

    /// Non-blocking admission for open-loop traffic: enqueue if a slot is
    /// free, otherwise return `Ok(false)` immediately (the caller counts
    /// an overload rejection; dropping `p` drops its response sender, so
    /// any held handle observes a receive error). Errors once closed.
    pub(crate) fn try_submit(&self, p: Pending) -> Result<bool, String> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err("serving engine is shut down".into());
        }
        if inner.queue.len() < self.capacity {
            inner.queue.push_back(p);
            self.not_empty.notify_all();
            Ok(true)
        } else {
            Ok(false)
        }
    }

    /// Close the queue: new submissions fail, workers drain what's left
    /// and then observe the closed flag.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Fail-fast close: close AND drop every queued request. Dropping a
    /// `Pending` drops its response sender, so blocked clients observe a
    /// receive error instead of hanging forever. Since PR 8 worker panics
    /// are supervised (batch requeued, worker re-bound), so this is the
    /// last-resort path for unrecoverable failures only — e.g. a worker
    /// that cannot re-bind a fresh step.
    pub fn abort(&self) {
        let drained = {
            let mut inner = self.inner.lock().unwrap();
            inner.closed = true;
            self.not_empty.notify_all();
            self.not_full.notify_all();
            std::mem::take(&mut inner.queue)
        };
        // Senders drop outside the lock.
        drop(drained);
    }

    /// Put already-admitted requests back into the queue (worker
    /// supervision: the in-flight batch of a panicked worker; shard
    /// failover: a dead shard's drained queue). Each request is re-inserted
    /// at its (priority, deadline, admission-id) urgency position — NOT
    /// blindly at the front — so a requeued low-priority batch can never
    /// sit physically ahead of a more urgent arrival in the drain/steal
    /// paths that consume the queue in physical order. Deliberately ignores
    /// both capacity (these requests already held admission — a transient
    /// overshoot beats dropping them) and the closed flag (a draining
    /// shutdown must still answer them).
    pub(crate) fn requeue(&self, batch: Vec<Pending>) {
        if batch.is_empty() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        for p in batch {
            let pos = inner
                .queue
                .iter()
                .position(|q| p.cmp_urgency(q).is_lt())
                .unwrap_or(inner.queue.len());
            inner.queue.insert(pos, p);
        }
        self.not_empty.notify_all();
    }

    /// Remove and return every queued request, most urgent first (shard
    /// failover: the router drains a Down shard and `requeue`s the batch
    /// into a surviving replica). Wakes blocked producers — though on a
    /// Down shard they are about to get a closed error anyway.
    pub(crate) fn drain_all(&self) -> Vec<Pending> {
        let mut inner = self.inner.lock().unwrap();
        let mut out: Vec<Pending> = inner.queue.drain(..).collect();
        out.sort_by(|a, b| a.cmp_urgency(b));
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    /// Remove the `n` least-urgent queued requests (work stealing between
    /// replicas). Returned most-urgent-first so a `requeue` at the target
    /// preserves relative order; the donor keeps its most urgent work, so
    /// stealing never delays the request a worker would pick next.
    pub(crate) fn steal_least_urgent(&self, n: usize) -> Vec<Pending> {
        if n == 0 {
            return Vec::new();
        }
        let mut inner = self.inner.lock().unwrap();
        let mut all: Vec<Pending> = inner.queue.drain(..).collect();
        all.sort_by(|a, b| a.cmp_urgency(b));
        let keep = all.len().saturating_sub(n);
        let stolen = all.split_off(keep);
        inner.queue.extend(all);
        if !stolen.is_empty() {
            self.not_full.notify_all();
        }
        stolen
    }

    /// Non-blocking admission that may displace: like `try_submit`, except
    /// that when the queue is full and `p`'s priority class strictly
    /// outranks the least-urgent queued request's, that victim is removed
    /// and handed back so the caller can answer it with an explicit status
    /// (graceful degradation under shrunken capacity — the lowest class is
    /// shed first, never silently). Same-class arrivals never displace
    /// (deadline churn); they are rejected as `Full`.
    pub(crate) fn try_submit_displacing(&self, p: Pending) -> Result<Admit, String> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err("serving engine is shut down".into());
        }
        if inner.queue.len() < self.capacity {
            inner.queue.push_back(p);
            self.not_empty.notify_all();
            return Ok(Admit::Admitted(None));
        }
        let victim_i = (0..inner.queue.len())
            .max_by(|&a, &b| inner.queue[a].cmp_urgency(&inner.queue[b]))
            .expect("capacity >= 1, a full queue is non-empty");
        if p.req.priority < inner.queue[victim_i].req.priority {
            let victim = inner.queue.remove(victim_i).expect("index in range");
            inner.queue.push_back(p);
            self.not_empty.notify_all();
            Ok(Admit::Admitted(Some(victim)))
        } else {
            Ok(Admit::Full)
        }
    }

    /// Whether the queue has been closed (the router's supervisor uses
    /// this to notice a shard whose engine aborted itself).
    pub(crate) fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Requests currently waiting (diagnostics).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Outcome of [`AdmissionQueue::try_submit_displacing`].
pub(crate) enum Admit {
    /// Admitted; `Some(victim)` carries a displaced less-urgent request
    /// that the caller must answer explicitly.
    Admitted(Option<Pending>),
    /// Queue full and the arrival does not outrank any queued class; the
    /// arrival was dropped (its handle observes a disconnect — the caller
    /// counts an overload rejection).
    Full,
}

/// One-shot completion channel for a request (engine + tests).
pub(crate) fn response_channel() -> (mpsc::Sender<Response>, mpsc::Receiver<Response>) {
    mpsc::channel()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(id: u64, task: usize) -> (Pending, mpsc::Receiver<Response>) {
        let (tx, rx) = response_channel();
        (
            Pending {
                req: Request { id, task, tokens: vec![1, 2, 3], priority: 0 },
                tx,
                enqueued: Instant::now(),
                deadline: None,
                admit_us: 0,
                batch_us: 0,
                panics: 0,
                solo: false,
            },
            rx,
        )
    }

    #[test]
    fn submit_and_close_semantics() {
        let q = AdmissionQueue::new(2);
        let (p0, _rx0) = pending(0, 0);
        let (p1, _rx1) = pending(1, 1);
        q.submit(p0).unwrap();
        q.submit(p1).unwrap();
        assert_eq!(q.len(), 2);
        q.close();
        let (p2, _rx2) = pending(2, 0);
        assert!(q.submit(p2).is_err(), "closed queue must reject submissions");
        // Already-admitted requests are still visible for draining.
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn bounded_capacity_blocks_until_a_worker_drains() {
        use std::sync::Arc;
        let q = Arc::new(AdmissionQueue::new(1));
        let (p0, _rx0) = pending(0, 0);
        q.submit(p0).unwrap();
        // A second submit must block until the queue has room.
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let (p1, rx1) = pending(1, 0);
            q2.submit(p1).map(|_| rx1)
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.len(), 1, "second submit should still be parked");
        // Drain one; the parked producer gets its slot.
        {
            let mut inner = q.inner.lock().unwrap();
            let _ = inner.queue.pop_front();
            q.not_full.notify_all();
        }
        h.join().unwrap().unwrap();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn try_submit_rejects_on_full_without_blocking() {
        let q = AdmissionQueue::new(1);
        let (p0, _rx0) = pending(0, 0);
        assert_eq!(q.try_submit(p0), Ok(true));
        let (p1, rx1) = pending(1, 0);
        assert_eq!(q.try_submit(p1), Ok(false), "full queue must reject, not block");
        // The rejected Pending was dropped with its sender: the handle
        // side observes a disconnect instead of hanging.
        assert!(rx1.recv().is_err());
        assert_eq!(q.len(), 1);
        q.close();
        let (p2, _rx2) = pending(2, 0);
        assert!(q.try_submit(p2).is_err(), "closed queue errors");
    }

    #[test]
    fn urgency_orders_priority_then_deadline_then_admission() {
        use std::cmp::Ordering;
        use std::time::Duration;
        let now = Instant::now();
        let mk = |id: u64, priority: u8, deadline: Option<Duration>| {
            let (tx, _rx) = response_channel();
            (
                Pending {
                    req: Request { id, task: 0, tokens: vec![1], priority },
                    tx,
                    enqueued: now,
                    deadline: deadline.map(|d| now + d),
                    admit_us: 0,
                    batch_us: 0,
                    panics: 0,
                    solo: false,
                },
                _rx,
            )
        };
        let (hi, _r0) = mk(5, 0, None);
        let (lo, _r1) = mk(1, 3, Some(Duration::from_millis(1)));
        assert_eq!(hi.cmp_urgency(&lo), Ordering::Less, "priority class dominates");
        let (soon, _r2) = mk(9, 1, Some(Duration::from_millis(5)));
        let (late, _r3) = mk(2, 1, Some(Duration::from_millis(50)));
        assert_eq!(soon.cmp_urgency(&late), Ordering::Less, "EDF within a class");
        let (none, _r4) = mk(0, 1, None);
        assert_eq!(soon.cmp_urgency(&none), Ordering::Less, "deadline-free sorts last");
        let (a, _r5) = mk(3, 1, None);
        let (b, _r6) = mk(4, 1, None);
        assert_eq!(a.cmp_urgency(&b), Ordering::Less, "admission order breaks ties");
        // Expiry is inclusive: now >= deadline counts as expired, so a
        // zero relative deadline is deterministically shed by any worker
        // that reaches it strictly after admission.
        let (z, _r7) = mk(7, 0, Some(Duration::ZERO));
        assert!(z.expired_at(now + Duration::from_nanos(1)));
        assert!(z.expired_at(now), "boundary instant counts as expired");
        assert!(!late.expired_at(now));
    }

    #[test]
    fn close_keeps_queued_requests_while_abort_errors_them() {
        // close(): already-admitted requests stay drainable — their
        // response channels are intact. abort(): queued requests are
        // dropped, so waiting clients see a disconnect, not a hang.
        let q = AdmissionQueue::new(4);
        let (p0, rx0) = pending(0, 0);
        let (p1, rx1) = pending(1, 0);
        q.submit(p0).unwrap();
        q.submit(p1).unwrap();
        q.close();
        assert_eq!(q.len(), 2, "close must not discard admitted work");
        // A worker can still drain and answer after close.
        let p = q.inner.lock().unwrap().queue.pop_front().unwrap();
        p.tx.send(Response {
            id: p.req.id,
            task: p.req.task,
            status: ResponseStatus::Ok,
            logits: vec![0.5, 0.5],
            batch_rows: 1,
            generation: 0,
            done_us: 0,
            stamps: StageStamps::default(),
            error: None,
        })
        .unwrap();
        assert_eq!(rx0.recv().unwrap().status, ResponseStatus::Ok);
        // abort() on the same queue drops the remainder: the client's
        // receive errors instead of blocking forever.
        q.abort();
        assert_eq!(q.len(), 0, "abort discards queued work");
        assert!(rx1.recv().is_err(), "aborted request must disconnect its handle");
    }

    #[test]
    fn producer_blocked_on_a_full_queue_wakes_with_an_error_on_close() {
        use std::sync::Arc;
        let q = Arc::new(AdmissionQueue::new(1));
        let (p0, _rx0) = pending(0, 0);
        q.submit(p0).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let (p1, _rx1) = pending(1, 0);
            q2.submit(p1)
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        let res = h.join().unwrap();
        assert!(res.is_err(), "blocked producer must wake with an error, not hang");
        assert_eq!(q.len(), 1, "the admitted request is still drainable");
    }

    #[test]
    fn producer_blocked_on_a_full_queue_wakes_with_an_error_on_abort() {
        use std::sync::Arc;
        let q = Arc::new(AdmissionQueue::new(1));
        let (p0, rx0) = pending(0, 0);
        q.submit(p0).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let (p1, _rx1) = pending(1, 0);
            q2.submit(p1)
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.abort();
        let res = h.join().unwrap();
        assert!(res.is_err(), "blocked producer must wake with an error, not hang");
        assert!(rx0.recv().is_err(), "abort drops the admitted request too");
    }

    #[test]
    fn requeue_front_loads_even_a_full_or_closed_queue() {
        let q = AdmissionQueue::new(1);
        let (p0, _rx0) = pending(5, 0);
        q.submit(p0).unwrap();
        q.close();
        // Supervision re-queues an in-flight batch: capacity and the
        // closed flag must not apply — this work already held admission.
        let (p1, _rx1) = pending(1, 0);
        let (p2, _rx2) = pending(2, 0);
        q.requeue(vec![p1, p2]);
        assert_eq!(q.len(), 3);
        // Same class, no deadlines: admission ids order the queue, so the
        // requeued batch (older ids) lands ahead of the queued tail.
        let inner = q.inner.lock().unwrap();
        let ids: Vec<u64> = inner.queue.iter().map(|p| p.req.id).collect();
        assert_eq!(ids, vec![1, 2, 5]);
    }

    fn pending_pri(id: u64, priority: u8) -> (Pending, mpsc::Receiver<Response>) {
        let (tx, rx) = response_channel();
        (
            Pending {
                req: Request { id, task: 0, tokens: vec![1, 2, 3], priority },
                tx,
                enqueued: Instant::now(),
                deadline: None,
                admit_us: 0,
                batch_us: 0,
                panics: 0,
                solo: false,
            },
            rx,
        )
    }

    #[test]
    fn requeue_respects_priority_ordering_over_front_of_line() {
        // Regression (PR 9): a requeued low-priority batch used to be
        // pushed blindly to the physical front, starving a newly admitted
        // high-priority request in every path that consumes the queue in
        // physical order. Re-insertion must go through (priority,
        // deadline, admission) urgency ordering instead.
        let q = AdmissionQueue::new(4);
        let (hi, _rx_hi) = pending_pri(10, 0);
        q.submit(hi).unwrap();
        let (lo1, _rx1) = pending_pri(1, 1);
        let (lo2, _rx2) = pending_pri(2, 1);
        q.requeue(vec![lo1, lo2]);
        let inner = q.inner.lock().unwrap();
        let ids: Vec<u64> = inner.queue.iter().map(|p| p.req.id).collect();
        assert_eq!(
            ids,
            vec![10, 1, 2],
            "priority-0 arrival must stay ahead of a requeued priority-1 batch"
        );
    }

    #[test]
    fn drain_all_returns_most_urgent_first_and_empties_the_queue() {
        let q = AdmissionQueue::new(4);
        let (a, _ra) = pending_pri(1, 1);
        let (b, _rb) = pending_pri(2, 0);
        let (c, _rc) = pending_pri(3, 1);
        q.requeue(vec![a, b, c]);
        let drained = q.drain_all();
        let ids: Vec<u64> = drained.iter().map(|p| p.req.id).collect();
        assert_eq!(ids, vec![2, 1, 3], "priority class first, then admission id");
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn steal_takes_the_least_urgent_and_leaves_the_donor_its_head() {
        let q = AdmissionQueue::new(4);
        let (a, _ra) = pending_pri(1, 0);
        let (b, _rb) = pending_pri(2, 1);
        let (c, _rc) = pending_pri(3, 0);
        q.requeue(vec![a, b, c]);
        let stolen = q.steal_least_urgent(2);
        let stolen_ids: Vec<u64> = stolen.iter().map(|p| p.req.id).collect();
        // Urgency order is [1, 3, 2]; the donor keeps its most urgent
        // request, and the stolen pair comes back most-urgent-first so a
        // requeue at the target preserves relative order.
        assert_eq!(stolen_ids, vec![3, 2]);
        let inner = q.inner.lock().unwrap();
        let kept: Vec<u64> = inner.queue.iter().map(|p| p.req.id).collect();
        assert_eq!(kept, vec![1]);
        drop(inner);
        assert!(q.steal_least_urgent(0).is_empty());
    }

    #[test]
    fn displacing_admission_sheds_the_lowest_class_first_never_silently() {
        let q = AdmissionQueue::new(1);
        let (lo, rx_lo) = pending_pri(1, 1);
        q.submit(lo).unwrap();
        // A strictly higher class displaces: the victim comes back to the
        // caller so it can be answered with an explicit status.
        let (hi, _rx_hi) = pending_pri(2, 0);
        match q.try_submit_displacing(hi).unwrap() {
            Admit::Admitted(Some(victim)) => assert_eq!(victim.req.id, 1),
            _ => panic!("higher class must displace on a full queue"),
        }
        // The displaced handle is still answerable — nothing was dropped.
        drop(rx_lo);
        // Same class does not displace (no deadline churn), nor does a
        // lower class: both are plain Full rejections.
        let (same, rx_same) = pending_pri(3, 0);
        assert!(matches!(q.try_submit_displacing(same).unwrap(), Admit::Full));
        assert!(rx_same.recv().is_err(), "rejected arrival disconnects its handle");
        let (worse, _rx_worse) = pending_pri(4, 1);
        assert!(matches!(q.try_submit_displacing(worse).unwrap(), Admit::Full));
        // Room available: plain admission, no victim.
        let _ = q.inner.lock().unwrap().queue.pop_front();
        let (ok, _rx_ok) = pending_pri(5, 1);
        assert!(matches!(q.try_submit_displacing(ok).unwrap(), Admit::Admitted(None)));
        q.close();
        let (late, _rx_late) = pending_pri(6, 0);
        assert!(q.try_submit_displacing(late).is_err(), "closed queue errors");
    }
}
