//! Deterministic closed-loop load generator + the `BENCH_pr5.json` record.
//!
//! C client threads each replay a seeded request stream against an
//! in-process [`ServingEngine`]: sample a task from the configured mix,
//! generate that request's tokens, submit, block on the response, repeat
//! (optionally with think time — the closed-loop "arrival pattern" knob:
//! zero think time is a saturating burst, larger values approach an open
//! trickle). Request *content* is a pure function of `(seed, client,
//! index)` — [`request_stream`] exposes exactly the stream a client
//! replays, which is what the parity and determinism tests in
//! `tests/serving.rs` re-derive — while timing (and therefore batch
//! composition) is free to vary; responses are bit-identical regardless.

use super::engine::ServingEngine;
use super::request::Response;
use crate::bench::Stats;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use anyhow::{anyhow, Result};
use std::time::{Duration, Instant};

/// Load-generation knobs.
#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Requests each client issues.
    pub requests_per_client: usize,
    /// Stream seed: request content is a pure function of (seed, client,
    /// request index).
    pub seed: u64,
    /// Per-task mix weights (len = engine num_tasks); empty = uniform.
    pub task_mix: Vec<f64>,
    /// Think time between a response and the client's next request (µs).
    pub think_us: u64,
}

impl Default for LoadGenConfig {
    fn default() -> LoadGenConfig {
        LoadGenConfig {
            clients: 4,
            requests_per_client: 25,
            seed: 7,
            task_mix: Vec::new(),
            think_us: 0,
        }
    }
}

/// What one load run measured.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub total_requests: usize,
    pub elapsed: f64,
    pub throughput_rps: f64,
    /// End-to-end (submit → response) latency in seconds.
    pub latency: Stats,
    /// Requests per task.
    pub per_task: Vec<u64>,
}

/// The deterministic request stream of one client: `(task, tokens)` for
/// request `index`. Tests replay this to compute reference responses for
/// the exact traffic a load run produced.
pub fn request_stream(
    cfg: &LoadGenConfig,
    num_tasks: usize,
    seq: usize,
    vocab: usize,
    client: usize,
    count: usize,
) -> Vec<(usize, Vec<i32>)> {
    let mut rng = client_rng(cfg.seed, client);
    let cum = cumulative_mix(&cfg.task_mix, num_tasks);
    (0..count)
        .map(|_| {
            let task = sample_task(&mut rng, &cum);
            let tokens = request_tokens(&mut rng, seq, vocab);
            (task, tokens)
        })
        .collect()
}

fn client_rng(seed: u64, client: usize) -> Pcg64 {
    Pcg64::with_stream(seed, 0x10ad ^ (client as u64).wrapping_mul(0x9e37_79b9))
}

/// One request's token ids: seq draws from `[1, vocab)` (0 is the pad id,
/// which the attention mask treats as absent — synthetic requests keep
/// every position real).
pub fn request_tokens(rng: &mut Pcg64, seq: usize, vocab: usize) -> Vec<i32> {
    (0..seq).map(|_| 1 + rng.uniform_usize(vocab - 1) as i32).collect()
}

fn cumulative_mix(weights: &[f64], num_tasks: usize) -> Vec<f64> {
    let w: Vec<f64> = if weights.is_empty() {
        vec![1.0; num_tasks]
    } else {
        assert_eq!(weights.len(), num_tasks, "task mix length != num tasks");
        assert!(
            weights.iter().all(|x| x.is_finite() && *x >= 0.0),
            "task mix weights must be finite and >= 0 (got {weights:?})"
        );
        weights.to_vec()
    };
    let total: f64 = w.iter().sum();
    assert!(total > 0.0, "task mix must have positive total weight");
    let mut acc = 0.0;
    w.iter()
        .map(|x| {
            acc += x / total;
            acc
        })
        .collect()
}

fn sample_task(rng: &mut Pcg64, cum: &[f64]) -> usize {
    let u = rng.uniform_f64();
    cum.iter().position(|&c| u < c).unwrap_or(cum.len() - 1)
}

/// Drive the engine with `cfg.clients` closed-loop clients and fold the
/// per-request latencies into a [`LoadReport`]. Responses are checked for
/// id/task consistency; logits validation belongs to the test suite.
///
/// A short warmup wave (round-robin over every task, sized to the worker
/// pool, its own RNG stream) runs before the clock starts and is excluded
/// from the latency/throughput measurements, so the recorded percentiles
/// reflect steady-state serving rather than worker bind + first-tick arena
/// growth + cold folds. (Engine-side counters — batches, cache folds —
/// still include the warmup ticks; folds happen once either way.)
pub fn run_load(engine: &ServingEngine, cfg: &LoadGenConfig) -> Result<LoadReport> {
    if cfg.clients == 0 || cfg.requests_per_client == 0 {
        anyhow::bail!(
            "load generator needs >= 1 client and >= 1 request per client \
             (got {} x {})",
            cfg.clients,
            cfg.requests_per_client
        );
    }
    let num_tasks = engine.config().num_tasks;
    let (seq, vocab) = (engine.seq_len(), engine.vocab());
    let (elapsed, per_client): (f64, Vec<(Vec<f64>, Vec<u64>)>) = engine.serve(|eng| {
        let mut wrng = Pcg64::with_stream(cfg.seed, 0x3a97);
        let warm = (eng.config().workers * 2).max(num_tasks);
        for i in 0..warm {
            let tokens = request_tokens(&mut wrng, seq, vocab);
            eng.submit(i % num_tasks, tokens)?
                .wait()
                .map_err(|e| anyhow!(e))?;
        }
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..cfg.clients)
                .map(|client| {
                    scope.spawn(move || -> Result<(Vec<f64>, Vec<u64>)> {
                        let stream = request_stream(
                            cfg,
                            num_tasks,
                            seq,
                            vocab,
                            client,
                            cfg.requests_per_client,
                        );
                        let mut lats = Vec::with_capacity(stream.len());
                        let mut per_task = vec![0u64; num_tasks];
                        for (task, tokens) in stream {
                            let sent = Instant::now();
                            let handle = eng.submit(task, tokens)?;
                            let resp: Response =
                                handle.wait().map_err(|e| anyhow!(e))?;
                            lats.push(sent.elapsed().as_secs_f64());
                            if resp.task != task {
                                return Err(anyhow!(
                                    "response task {} for a task-{task} request",
                                    resp.task
                                ));
                            }
                            per_task[task] += 1;
                            if cfg.think_us > 0 {
                                std::thread::sleep(Duration::from_micros(cfg.think_us));
                            }
                        }
                        Ok((lats, per_task))
                    })
                })
                .collect();
            let mut results = Vec::with_capacity(handles.len());
            for h in handles {
                results.push(h.join().map_err(|_| anyhow!("load client panicked"))??);
            }
            Ok((t0.elapsed().as_secs_f64(), results))
        })
    })??;
    let mut lats = Vec::new();
    let mut per_task = vec![0u64; num_tasks];
    for (l, p) in per_client {
        lats.extend(l);
        for (dst, src) in per_task.iter_mut().zip(&p) {
            *dst += src;
        }
    }
    let total = lats.len();
    Ok(LoadReport {
        total_requests: total,
        elapsed,
        throughput_rps: total as f64 / elapsed.max(1e-9),
        latency: Stats::from_samples(lats),
        per_task,
    })
}

/// Assemble the `BENCH_pr5.json` document from a load run: latency
/// percentiles, throughput, the batch-size histogram, and cache counters.
pub fn report_json(engine: &ServingEngine, cfg: &LoadGenConfig, report: &LoadReport) -> Json {
    let ecfg = engine.config();
    let stats = engine.stats();
    let cache = engine.cache_stats();
    let lookups = cache.hits + cache.folds;
    let mean_fill = if stats.batches > 0 {
        stats.requests as f64 / stats.batches as f64
    } else {
        0.0
    };
    Json::obj(vec![
        ("bench", Json::str("serving_engine")),
        (
            "config",
            Json::obj(vec![
                ("model", Json::str(ecfg.model.name())),
                ("adapter", Json::str(ecfg.adapter.name())),
                ("rank", Json::num(ecfg.rank as f64)),
                ("num_tasks", Json::num(ecfg.num_tasks as f64)),
                ("classes", Json::num(ecfg.classes as f64)),
                ("max_batch", Json::num(ecfg.max_batch as f64)),
                (
                    "batch_deadline_ms",
                    Json::num(ecfg.batch_deadline.as_secs_f64() * 1e3),
                ),
                ("workers", Json::num(ecfg.workers as f64)),
                ("cache_capacity", Json::num(ecfg.cache_capacity as f64)),
                ("clients", Json::num(cfg.clients as f64)),
                ("requests_per_client", Json::num(cfg.requests_per_client as f64)),
                ("seed", Json::num(cfg.seed as f64)),
                ("think_us", Json::num(cfg.think_us as f64)),
            ]),
        ),
        (
            "load",
            Json::obj(vec![
                ("requests", Json::num(report.total_requests as f64)),
                ("elapsed_s", Json::num(report.elapsed)),
                ("throughput_rps", Json::num(report.throughput_rps)),
                (
                    "latency_s",
                    Json::obj(vec![
                        ("mean", Json::num(report.latency.mean)),
                        ("p50", Json::num(report.latency.p50)),
                        ("p95", Json::num(report.latency.p95)),
                        ("p99", Json::num(report.latency.p99)),
                    ]),
                ),
                (
                    "per_task",
                    Json::Arr(report.per_task.iter().map(|&n| Json::num(n as f64)).collect()),
                ),
            ]),
        ),
        (
            "batches",
            Json::obj(vec![
                ("count", Json::num(stats.batches as f64)),
                ("mean_fill", Json::num(mean_fill)),
                (
                    "size_histogram",
                    Json::Arr(
                        stats.batch_hist.iter().map(|&n| Json::num(n as f64)).collect(),
                    ),
                ),
            ]),
        ),
        (
            "cache",
            Json::obj(vec![
                ("hits", Json::num(cache.hits as f64)),
                ("folds", Json::num(cache.folds as f64)),
                ("evictions", Json::num(cache.evictions as f64)),
                ("reloads", Json::num(cache.reloads as f64)),
                (
                    "hit_rate",
                    Json::num(if lookups > 0 {
                        cache.hits as f64 / lookups as f64
                    } else {
                        0.0
                    }),
                ),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_stream_is_deterministic_and_respects_the_mix() {
        let cfg = LoadGenConfig {
            seed: 11,
            task_mix: vec![1.0, 0.0, 3.0],
            ..Default::default()
        };
        let a = request_stream(&cfg, 3, 8, 64, 0, 50);
        let b = request_stream(&cfg, 3, 8, 64, 0, 50);
        assert_eq!(a, b, "same (seed, client) must replay the same stream");
        let other = request_stream(&cfg, 3, 8, 64, 1, 50);
        assert_ne!(a, other, "clients must draw distinct streams");
        // Zero-weight tasks never appear; tokens stay in [1, vocab).
        for (task, tokens) in &a {
            assert_ne!(*task, 1, "zero-weight task sampled");
            assert!(tokens.iter().all(|&t| t >= 1 && t < 64));
            assert_eq!(tokens.len(), 8);
        }
        // The heavier task dominates.
        let t2 = a.iter().filter(|(t, _)| *t == 2).count();
        assert!(t2 > 25, "weight-3 task drew only {t2}/50");
    }

    #[test]
    #[should_panic(expected = "task mix length")]
    fn wrong_mix_length_is_rejected() {
        let cfg = LoadGenConfig { task_mix: vec![1.0], ..Default::default() };
        let _ = request_stream(&cfg, 3, 8, 64, 0, 1);
    }
}
