//! Deterministic load generation: closed-loop clients (`BENCH_pr5.json`),
//! an open-loop Poisson arrival mode, and the overload sweep behind
//! `BENCH_pr6.json`.
//!
//! **Closed-loop** ([`run_load`]): C client threads each replay a seeded
//! request stream — sample a task from the mix, generate tokens, submit,
//! block on the response, repeat (optional think time). Offered load is
//! coupled to service rate (a slow server slows its clients), which makes
//! it a *capacity* probe, not an overload probe. Request content is a pure
//! function of `(seed, client, index)` — [`request_stream`] exposes
//! exactly the stream a client replays — while timing (and therefore
//! batch composition) is free to vary; responses are bit-identical
//! regardless.
//!
//! **Open-loop** ([`run_open_loop`]): a single arrival thread fires
//! requests at a fixed Poisson rate regardless of how the engine is doing
//! — admission is non-blocking (`try_submit_with`), so a saturated queue
//! rejects arrivals instead of slowing them down. This is the only
//! honest way to measure overload: offered load stays at the configured
//! multiple of capacity while the engine sheds expired requests and
//! refuses full-queue arrivals. Latency is measured on the engine's
//! `done_us` clock (submit → completion), so a lagging collector cannot
//! inflate the tail.
//!
//! **Overload sweep** ([`run_overload_bench`]): one `serve` session —
//! warmup, a closed-loop capacity measurement, then an open-loop level at
//! each requested multiple of that capacity — reported as per-window
//! [`EngineStats`] deltas so warmup and earlier levels never contaminate
//! a level's numbers.

use super::engine::{EngineStats, ServeTarget, ServingEngine};
use super::request::{Response, ResponseHandle, ResponseStatus};
use crate::bench::Stats;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use anyhow::{anyhow, Result};
use std::time::{Duration, Instant};

/// Load-generation knobs.
#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Requests each client issues.
    pub requests_per_client: usize,
    /// Stream seed: request content is a pure function of (seed, client,
    /// request index).
    pub seed: u64,
    /// Per-task mix weights (len = engine num_tasks); empty = uniform.
    pub task_mix: Vec<f64>,
    /// Think time between a response and the client's next request (µs).
    pub think_us: u64,
    /// Relative deadline attached to every request (None = no deadline).
    pub deadline: Option<Duration>,
    /// Priority class for every request (lower = more urgent).
    pub priority: u8,
}

impl Default for LoadGenConfig {
    fn default() -> LoadGenConfig {
        LoadGenConfig {
            clients: 4,
            requests_per_client: 25,
            seed: 7,
            task_mix: Vec::new(),
            think_us: 0,
            deadline: None,
            priority: 0,
        }
    }
}

/// Per-stage latency breakdown of computed responses, from the
/// [`super::request::StageStamps`] every `Ok` response carries (always on
/// — stage stamping is cheap clock reads, independent of the armed
/// tracer). All stats are in **seconds**.
#[derive(Clone, Debug)]
pub struct StageBreakdown {
    /// Admission → batch-formed (time spent queued).
    pub queue_wait: Stats,
    /// Batch-formed → tick-start (fold lookup + dispatch).
    pub batch_wait: Stats,
    /// Tick-start → tick-end (the forward pass).
    pub compute: Stats,
    /// Tick-end → response delivered (`done_us`).
    pub respond: Stats,
}

impl StageBreakdown {
    /// Build from `[admit, batch, start, end, done]` µs stamp rows
    /// (complete lifecycles only); `None` when there are no rows — e.g.
    /// nothing completed, or a pre-stamp network peer.
    pub fn from_stamp_rows(rows: &[[u64; 5]]) -> Option<StageBreakdown> {
        if rows.is_empty() {
            return None;
        }
        let stage = |lo: usize, hi: usize| {
            Stats::from_samples(
                rows.iter().map(|r| r[hi].saturating_sub(r[lo]) as f64 * 1e-6).collect(),
            )
        };
        Some(StageBreakdown {
            queue_wait: stage(0, 1),
            batch_wait: stage(1, 2),
            compute: stage(2, 3),
            respond: stage(3, 4),
        })
    }
}

/// What one closed-loop run measured.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub total_requests: usize,
    pub elapsed: f64,
    pub throughput_rps: f64,
    /// End-to-end (submit → response) latency in seconds, computed
    /// responses only.
    pub latency: Stats,
    /// Per-stage breakdown of the same responses (queue-wait / batch-wait /
    /// compute / respond); None when nothing completed.
    pub stages: Option<StageBreakdown>,
    /// Computed responses per task.
    pub per_task: Vec<u64>,
    /// Responses answered `Expired` (only possible with a deadline set).
    pub expired: usize,
    /// Responses answered `Error` (a request quarantined after repeated
    /// execution failure — zero unless faults are armed).
    pub errors: usize,
    /// Engine counters for the measured window only: a snapshot delta that
    /// excludes warmup traffic (and, inside a sweep, earlier phases).
    pub engine: EngineStats,
}

/// The deterministic request stream of one client: `(task, tokens)` for
/// request `index`. Tests replay this to compute reference responses for
/// the exact traffic a load run produced.
pub fn request_stream(
    cfg: &LoadGenConfig,
    num_tasks: usize,
    seq: usize,
    vocab: usize,
    client: usize,
    count: usize,
) -> Vec<(usize, Vec<i32>)> {
    let mut rng = client_rng(cfg.seed, client);
    let cum = cumulative_mix(&cfg.task_mix, num_tasks);
    (0..count)
        .map(|_| {
            let task = sample_task(&mut rng, &cum);
            let tokens = request_tokens(&mut rng, seq, vocab);
            (task, tokens)
        })
        .collect()
}

fn client_rng(seed: u64, client: usize) -> Pcg64 {
    Pcg64::with_stream(seed, 0x10ad ^ (client as u64).wrapping_mul(0x9e37_79b9))
}

/// One request's token ids: seq draws from `[1, vocab)` (0 is the pad id,
/// which the attention mask treats as absent — synthetic requests keep
/// every position real).
///
/// A degenerate single-token vocabulary has no non-pad ids to draw, so the
/// request is all-pad (`vec![0; seq]`) — the only well-formed request such
/// a model can receive. The guard matters: `vocab == 1` used to reach
/// `Pcg64::uniform_usize(0)`, whose empty-range contract panics.
pub fn request_tokens(rng: &mut Pcg64, seq: usize, vocab: usize) -> Vec<i32> {
    assert!(vocab >= 1, "request_tokens needs a vocabulary of at least the pad id");
    if vocab == 1 {
        return vec![0; seq];
    }
    (0..seq).map(|_| 1 + rng.uniform_usize(vocab - 1) as i32).collect()
}

fn cumulative_mix(weights: &[f64], num_tasks: usize) -> Vec<f64> {
    let w: Vec<f64> = if weights.is_empty() {
        vec![1.0; num_tasks]
    } else {
        assert_eq!(weights.len(), num_tasks, "task mix length != num tasks");
        assert!(
            weights.iter().all(|x| x.is_finite() && *x >= 0.0),
            "task mix weights must be finite and >= 0 (got {weights:?})"
        );
        weights.to_vec()
    };
    let total: f64 = w.iter().sum();
    assert!(total > 0.0, "task mix must have positive total weight");
    let mut acc = 0.0;
    w.iter()
        .map(|x| {
            acc += x / total;
            acc
        })
        .collect()
}

fn sample_task(rng: &mut Pcg64, cum: &[f64]) -> usize {
    let u = rng.uniform_f64();
    cum.iter().position(|&c| u < c).unwrap_or(cum.len() - 1)
}

/// Warm the serve target before a measured window: a round-robin wave over
/// every task, sized to the (total) worker pool, on its own RNG stream.
/// Covers worker bind + first-tick arena growth + the cold fold of each
/// task's adapter — on a sharded target the wave is large enough to reach
/// every shard's workers.
pub fn warmup_in<T: ServeTarget>(eng: &T, seed: u64) -> Result<()> {
    let num_tasks = eng.num_tasks();
    let (seq, vocab) = (eng.seq_len(), eng.vocab());
    let mut wrng = Pcg64::with_stream(seed, 0x3a97);
    let warm = (eng.workers() * 2).max(num_tasks);
    for i in 0..warm {
        let tokens = request_tokens(&mut wrng, seq, vocab);
        eng.submit_with(i % num_tasks, tokens, None, 0)?.wait().map_err(|e| anyhow!(e))?;
    }
    Ok(())
}

/// Closed-loop clients against a serve target whose worker pool is already
/// running (call inside a `serve` driver — engine or router — after
/// [`warmup_in`]). The report's engine counters are the delta over this
/// window only.
pub fn closed_loop_in<T: ServeTarget>(eng: &T, cfg: &LoadGenConfig) -> Result<LoadReport> {
    if cfg.clients == 0 || cfg.requests_per_client == 0 {
        anyhow::bail!(
            "load generator needs >= 1 client and >= 1 request per client \
             (got {} x {})",
            cfg.clients,
            cfg.requests_per_client
        );
    }
    let num_tasks = eng.num_tasks();
    let (seq, vocab) = (eng.seq_len(), eng.vocab());
    let base = eng.stats();
    let t0 = Instant::now();
    type ClientOut = (Vec<f64>, Vec<[u64; 5]>, Vec<u64>, usize, usize);
    let per_client: Vec<ClientOut> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.clients)
            .map(|client| {
                scope.spawn(move || -> Result<ClientOut> {
                    let stream = request_stream(
                        cfg,
                        num_tasks,
                        seq,
                        vocab,
                        client,
                        cfg.requests_per_client,
                    );
                    let mut lats = Vec::with_capacity(stream.len());
                    let mut stamp_rows = Vec::with_capacity(stream.len());
                    let mut per_task = vec![0u64; num_tasks];
                    let (mut expired, mut errors) = (0usize, 0usize);
                    for (task, tokens) in stream {
                        let sent = Instant::now();
                        let handle =
                            eng.submit_with(task, tokens, cfg.deadline, cfg.priority)?;
                        let resp: Response = handle.wait().map_err(|e| anyhow!(e))?;
                        if resp.status != ResponseStatus::Error && resp.task != task {
                            return Err(anyhow!(
                                "response task {} for a task-{task} request",
                                resp.task
                            ));
                        }
                        match resp.status {
                            ResponseStatus::Ok => {
                                lats.push(sent.elapsed().as_secs_f64());
                                per_task[task] += 1;
                                if resp.stamps.complete() {
                                    stamp_rows.push([
                                        resp.stamps.admit_us,
                                        resp.stamps.batch_us,
                                        resp.stamps.start_us,
                                        resp.stamps.end_us,
                                        resp.done_us,
                                    ]);
                                }
                            }
                            ResponseStatus::Expired => expired += 1,
                            ResponseStatus::Error => errors += 1,
                        }
                        if cfg.think_us > 0 {
                            std::thread::sleep(Duration::from_micros(cfg.think_us));
                        }
                    }
                    Ok((lats, stamp_rows, per_task, expired, errors))
                })
            })
            .collect();
        let mut results = Vec::with_capacity(handles.len());
        for h in handles {
            results.push(h.join().map_err(|_| anyhow!("load client panicked"))??);
        }
        Ok::<_, anyhow::Error>(results)
    })?;
    let elapsed = t0.elapsed().as_secs_f64();
    let mut lats = Vec::new();
    let mut stamp_rows = Vec::new();
    let mut per_task = vec![0u64; num_tasks];
    let (mut expired, mut errors) = (0usize, 0usize);
    for (l, s, p, e, x) in per_client {
        lats.extend(l);
        stamp_rows.extend(s);
        expired += e;
        errors += x;
        for (dst, src) in per_task.iter_mut().zip(&p) {
            *dst += src;
        }
    }
    let total = lats.len() + expired + errors;
    Ok(LoadReport {
        total_requests: total,
        elapsed,
        throughput_rps: lats.len() as f64 / elapsed.max(1e-9),
        latency: Stats::from_samples(lats),
        stages: StageBreakdown::from_stamp_rows(&stamp_rows),
        per_task,
        expired,
        errors,
        engine: eng.stats().delta_since(&base),
    })
}

/// Drive the engine with `cfg.clients` closed-loop clients and fold the
/// per-request latencies into a [`LoadReport`]. The warmup wave runs
/// before the clock starts; the report's latency, throughput, *and engine
/// counters* (mean fill, batch histogram, queue waits) cover the measured
/// window only — cumulative counters would let warmup ticks contaminate
/// the fill statistics. (Cache counters stay cumulative: folds happen once
/// either way and belong to the engine's lifetime, not a window.)
pub fn run_load<T: ServeTarget>(engine: &T, cfg: &LoadGenConfig) -> Result<LoadReport> {
    engine.serve_session(|eng| {
        warmup_in(eng, cfg.seed)?;
        closed_loop_in(eng, cfg)
    })?
}

/// Open-loop (Poisson) load knobs.
#[derive(Clone, Debug)]
pub struct OpenLoopConfig {
    /// Offered arrival rate, requests/second.
    pub rate_rps: f64,
    /// Total arrivals to offer.
    pub requests: usize,
    pub seed: u64,
    /// Stream tag — give each level of a sweep its own so request content
    /// differs across levels.
    pub stream: usize,
    /// Per-task mix weights (empty = uniform).
    pub task_mix: Vec<f64>,
    /// Relative deadline per request. Also the goodput criterion: a
    /// computed response that finished after it does not count.
    pub deadline: Option<Duration>,
    pub priority: u8,
}

/// What one open-loop window measured.
#[derive(Clone, Debug)]
pub struct OpenLoopReport {
    /// Arrivals generated.
    pub offered: usize,
    /// Arrivals admitted to the queue.
    pub admitted: usize,
    /// Arrivals refused because the queue was full.
    pub rejected: usize,
    /// Computed responses.
    pub ok: usize,
    /// Responses shed with `Expired`.
    pub expired: usize,
    /// Responses answered `Error` (quarantined requests — zero unless
    /// faults are armed).
    pub errors: usize,
    /// Admitted requests dropped without a response (worker failure only —
    /// zero on a clean run, asserted by the drain test).
    pub dropped: usize,
    /// Computed responses that also met their deadline (== `ok` when no
    /// deadline is configured).
    pub deadline_met: usize,
    /// First arrival → last response, seconds (engine clock).
    pub elapsed: f64,
    /// Arrivals actually offered per second (sleep jitter makes this
    /// slightly off the configured rate).
    pub offered_rps: f64,
    /// Deadline-meeting responses per second — the number overload is
    /// about.
    pub goodput_rps: f64,
    /// Computed responses per second (ignores deadlines).
    pub achieved_rps: f64,
    /// submit → done latency of computed responses (engine `done_us`
    /// clock); None when nothing completed.
    pub latency: Option<Stats>,
    /// Per-stage breakdown of computed responses; None when nothing
    /// completed.
    pub stages: Option<StageBreakdown>,
    /// Engine counters for this window only.
    pub engine: EngineStats,
}

/// Open-loop Poisson arrivals against a running serve target (call inside
/// a `serve` driver — engine or router). Arrivals are paced on an absolute
/// schedule — if the generator falls behind it bursts to catch up, so the
/// *average* offered rate holds. Admission never blocks: a full queue
/// counts a rejection and the arrival process moves on (on a router, a
/// full replica set may instead displace the lowest priority class).
pub fn open_loop_in<T: ServeTarget>(eng: &T, cfg: &OpenLoopConfig) -> Result<OpenLoopReport> {
    if cfg.requests == 0 || !(cfg.rate_rps > 0.0) || !cfg.rate_rps.is_finite() {
        anyhow::bail!(
            "open loop needs >= 1 request and a positive finite rate (got {} @ {} rps)",
            cfg.requests,
            cfg.rate_rps
        );
    }
    let num_tasks = eng.num_tasks();
    let (seq, vocab) = (eng.seq_len(), eng.vocab());
    let cum = cumulative_mix(&cfg.task_mix, num_tasks);
    let mut rng = client_rng(cfg.seed, 0x0bee ^ cfg.stream);
    let base = eng.stats();
    let deadline_us = cfg.deadline.map(|d| d.as_micros() as u64);

    let start = Instant::now();
    let t0_us = eng.now_us();
    let mut next_at = 0f64; // seconds since `start`, absolute schedule
    let mut admitted: Vec<(u64, ResponseHandle)> = Vec::with_capacity(cfg.requests);
    let mut rejected = 0usize;
    for _ in 0..cfg.requests {
        // Exponential inter-arrival gap: -ln(1-U)/λ, U ∈ [0, 1).
        next_at += -(1.0 - rng.uniform_f64()).ln() / cfg.rate_rps;
        let due = Duration::from_secs_f64(next_at);
        let now = start.elapsed();
        if due > now {
            std::thread::sleep(due - now);
        }
        let task = sample_task(&mut rng, &cum);
        let tokens = request_tokens(&mut rng, seq, vocab);
        let submit_us = eng.now_us();
        match eng.try_submit_with(task, tokens, cfg.deadline, cfg.priority)? {
            Some(handle) => admitted.push((submit_us, handle)),
            None => rejected += 1,
        }
    }
    let arrival_window = start.elapsed().as_secs_f64();

    // Collect. Handles buffer their responses, so waiting after the
    // arrival window costs nothing; latency uses engine `done_us` stamps
    // and is therefore independent of collection order.
    let n_admitted = admitted.len();
    let (mut ok, mut expired, mut dropped, mut met) = (0usize, 0usize, 0usize, 0usize);
    let mut errors = 0usize;
    let mut lats = Vec::with_capacity(n_admitted);
    let mut stamp_rows = Vec::with_capacity(n_admitted);
    let mut last_done_us = t0_us;
    for (submit_us, handle) in admitted {
        match handle.wait() {
            Ok(resp) => {
                last_done_us = last_done_us.max(resp.done_us);
                match resp.status {
                    ResponseStatus::Ok => {
                        ok += 1;
                        let lat_us = resp.done_us.saturating_sub(submit_us);
                        lats.push(lat_us as f64 * 1e-6);
                        if resp.stamps.complete() {
                            stamp_rows.push([
                                resp.stamps.admit_us,
                                resp.stamps.batch_us,
                                resp.stamps.start_us,
                                resp.stamps.end_us,
                                resp.done_us,
                            ]);
                        }
                        let in_time = match deadline_us {
                            None => true,
                            Some(d) => lat_us <= d,
                        };
                        if in_time {
                            met += 1;
                        }
                    }
                    ResponseStatus::Expired => expired += 1,
                    ResponseStatus::Error => errors += 1,
                }
            }
            Err(_) => dropped += 1,
        }
    }
    let elapsed = ((last_done_us - t0_us) as f64 * 1e-6).max(arrival_window).max(1e-9);
    Ok(OpenLoopReport {
        offered: cfg.requests,
        admitted: n_admitted,
        rejected,
        ok,
        expired,
        errors,
        dropped,
        deadline_met: met,
        elapsed,
        offered_rps: cfg.requests as f64 / arrival_window.max(1e-9),
        goodput_rps: met as f64 / elapsed,
        achieved_rps: ok as f64 / elapsed,
        latency: if lats.is_empty() { None } else { Some(Stats::from_samples(lats)) },
        stages: StageBreakdown::from_stamp_rows(&stamp_rows),
        engine: eng.stats().delta_since(&base),
    })
}

/// One full open-loop run: spawn the pool(s), warm up, offer, drain.
pub fn run_open_loop<T: ServeTarget>(
    engine: &T,
    cfg: &OpenLoopConfig,
) -> Result<OpenLoopReport> {
    engine.serve_session(|eng| {
        warmup_in(eng, cfg.seed)?;
        open_loop_in(eng, cfg)
    })?
}

/// Overload-sweep knobs (the `BENCH_pr6.json` experiment).
#[derive(Clone, Debug)]
pub struct OverloadConfig {
    /// Closed-loop phase that measures saturation capacity.
    pub capacity: LoadGenConfig,
    /// Offered-load multiples of the measured capacity, one open-loop
    /// level each.
    pub mults: Vec<f64>,
    /// Arrivals offered per level.
    pub requests_per_level: usize,
    /// Relative deadline per request at every level (the shed/goodput
    /// criterion).
    pub deadline: Duration,
    pub priority: u8,
}

impl Default for OverloadConfig {
    fn default() -> OverloadConfig {
        OverloadConfig {
            capacity: LoadGenConfig::default(),
            mults: vec![0.5, 1.0, 2.0, 4.0],
            requests_per_level: 200,
            deadline: Duration::from_millis(50),
            priority: 0,
        }
    }
}

/// What the sweep measured.
#[derive(Clone, Debug)]
pub struct OverloadReport {
    /// The closed-loop capacity phase.
    pub capacity: LoadReport,
    /// Saturation throughput the levels are scaled from, requests/s.
    pub capacity_rps: f64,
    /// `(multiple, open-loop report)` per level, in run order.
    pub levels: Vec<(f64, OpenLoopReport)>,
}

/// Measure capacity closed-loop, then offer open-loop Poisson load at each
/// multiple of it — all inside ONE `serve` session (an engine cannot serve
/// twice: `serve` closes the queue on exit). Each phase reports its own
/// [`EngineStats`] window.
pub fn run_overload_bench(
    engine: &ServingEngine,
    cfg: &OverloadConfig,
) -> Result<OverloadReport> {
    if cfg.mults.is_empty() {
        anyhow::bail!("overload sweep needs at least one load multiple");
    }
    if !(cfg.deadline > Duration::ZERO) {
        anyhow::bail!("overload sweep needs a positive deadline (it defines goodput)");
    }
    engine.serve(|eng| {
        warmup_in(eng, cfg.capacity.seed)?;
        let capacity = closed_loop_in(eng, &cfg.capacity)?;
        let capacity_rps = capacity.throughput_rps.max(1.0);
        let mut levels = Vec::with_capacity(cfg.mults.len());
        for (i, &mult) in cfg.mults.iter().enumerate() {
            if !(mult > 0.0) || !mult.is_finite() {
                anyhow::bail!("load multiple must be positive and finite (got {mult})");
            }
            let ol = OpenLoopConfig {
                rate_rps: capacity_rps * mult,
                requests: cfg.requests_per_level,
                seed: cfg.capacity.seed,
                stream: i + 1,
                task_mix: cfg.capacity.task_mix.clone(),
                deadline: Some(cfg.deadline),
                priority: cfg.priority,
            };
            levels.push((mult, open_loop_in(eng, &ol)?));
        }
        Ok(OverloadReport { capacity, capacity_rps, levels })
    })?
}

fn latency_json(s: &Stats) -> Json {
    Json::obj(vec![
        ("mean", Json::num(s.mean)),
        ("p50", Json::num(s.p50)),
        ("p95", Json::num(s.p95)),
        ("p99", Json::num(s.p99)),
    ])
}

/// JSON for a [`StageBreakdown`] — p50/p95/p99 per lifecycle stage, in
/// seconds (shared by the pr5/pr6/pr8 report emitters and the CLI's
/// `--metrics-out` dump).
pub fn stage_json(b: &StageBreakdown) -> Json {
    Json::obj(vec![
        ("queue_wait_s", latency_json(&b.queue_wait)),
        ("batch_wait_s", latency_json(&b.batch_wait)),
        ("compute_s", latency_json(&b.compute)),
        ("respond_s", latency_json(&b.respond)),
    ])
}

fn engine_window_json(stats: &EngineStats) -> Json {
    let mean_fill = if stats.batches > 0 {
        stats.requests as f64 / stats.batches as f64
    } else {
        0.0
    };
    Json::obj(vec![
        ("batches", Json::num(stats.batches as f64)),
        ("requests", Json::num(stats.requests as f64)),
        ("shed", Json::num(stats.shed as f64)),
        ("rejected", Json::num(stats.rejected as f64)),
        ("mean_fill", Json::num(mean_fill)),
        ("queue_wait_mean_ms", Json::num(stats.queue_wait_mean_s() * 1e3)),
        ("queue_wait_max_ms", Json::num(stats.queue_us_max as f64 * 1e-3)),
        ("worker_restarts", Json::num(stats.worker_restarts as f64)),
        ("quarantined", Json::num(stats.quarantined as f64)),
        ("requeued", Json::num(stats.requeued as f64)),
        (
            "size_histogram",
            Json::Arr(stats.batch_hist.iter().map(|&n| Json::num(n as f64)).collect()),
        ),
    ])
}

/// Assemble the `BENCH_pr5.json` document from a closed-loop run: latency
/// percentiles, throughput, the measured window's batch statistics, and
/// cache counters.
pub fn report_json(engine: &ServingEngine, cfg: &LoadGenConfig, report: &LoadReport) -> Json {
    let ecfg = engine.config();
    let stats = &report.engine;
    let cache = engine.cache_stats();
    let lookups = cache.hits + cache.folds;
    let mean_fill = if stats.batches > 0 {
        stats.requests as f64 / stats.batches as f64
    } else {
        0.0
    };
    Json::obj(vec![
        ("bench", Json::str("serving_engine")),
        (
            "config",
            Json::obj(vec![
                ("model", Json::str(ecfg.model.name())),
                ("adapter", Json::str(ecfg.adapter.name())),
                ("rank", Json::num(ecfg.rank as f64)),
                ("num_tasks", Json::num(ecfg.num_tasks as f64)),
                ("classes", Json::num(ecfg.classes as f64)),
                ("max_batch", Json::num(ecfg.max_batch as f64)),
                (
                    "batch_deadline_ms",
                    Json::num(ecfg.batch_deadline.as_secs_f64() * 1e3),
                ),
                ("workers", Json::num(ecfg.workers as f64)),
                ("cache_capacity_bytes", Json::num(ecfg.cache_capacity_bytes as f64)),
                ("serve_dtype", Json::str(ecfg.dtype.name())),
                ("clients", Json::num(cfg.clients as f64)),
                ("requests_per_client", Json::num(cfg.requests_per_client as f64)),
                ("seed", Json::num(cfg.seed as f64)),
                ("think_us", Json::num(cfg.think_us as f64)),
                (
                    "deadline_ms",
                    Json::num(cfg.deadline.map_or(0.0, |d| d.as_secs_f64() * 1e3)),
                ),
                ("priority", Json::num(cfg.priority as f64)),
            ]),
        ),
        (
            "load",
            Json::obj(vec![
                ("requests", Json::num(report.total_requests as f64)),
                ("elapsed_s", Json::num(report.elapsed)),
                ("throughput_rps", Json::num(report.throughput_rps)),
                ("expired", Json::num(report.expired as f64)),
                ("latency_s", latency_json(&report.latency)),
                ("stages", report.stages.as_ref().map_or(Json::Null, stage_json)),
                (
                    "per_task",
                    Json::Arr(report.per_task.iter().map(|&n| Json::num(n as f64)).collect()),
                ),
            ]),
        ),
        (
            "batches",
            Json::obj(vec![
                ("count", Json::num(stats.batches as f64)),
                ("mean_fill", Json::num(mean_fill)),
                (
                    "size_histogram",
                    Json::Arr(
                        stats.batch_hist.iter().map(|&n| Json::num(n as f64)).collect(),
                    ),
                ),
            ]),
        ),
        (
            "cache",
            Json::obj(vec![
                ("hits", Json::num(cache.hits as f64)),
                ("folds", Json::num(cache.folds as f64)),
                ("evictions", Json::num(cache.evictions as f64)),
                ("reloads", Json::num(cache.reloads as f64)),
                ("resident_bytes", Json::num(cache.bytes as f64)),
                (
                    "hit_rate",
                    Json::num(if lookups > 0 {
                        cache.hits as f64 / lookups as f64
                    } else {
                        0.0
                    }),
                ),
            ]),
        ),
    ])
}

/// Assemble the `BENCH_pr6.json` document from an overload sweep: the
/// measured capacity, then per level the offered rate, admission/shed
/// accounting, goodput, and the tail of the computed-response latencies.
pub fn overload_report_json(
    engine: &ServingEngine,
    cfg: &OverloadConfig,
    report: &OverloadReport,
) -> Json {
    let ecfg = engine.config();
    let levels = report
        .levels
        .iter()
        .map(|(mult, r)| {
            Json::obj(vec![
                ("mult", Json::num(*mult)),
                ("offered", Json::num(r.offered as f64)),
                ("offered_rps", Json::num(r.offered_rps)),
                ("admitted", Json::num(r.admitted as f64)),
                ("rejected_full", Json::num(r.rejected as f64)),
                ("ok", Json::num(r.ok as f64)),
                ("shed_expired", Json::num(r.expired as f64)),
                ("errors", Json::num(r.errors as f64)),
                ("dropped", Json::num(r.dropped as f64)),
                ("deadline_met", Json::num(r.deadline_met as f64)),
                ("elapsed_s", Json::num(r.elapsed)),
                ("goodput_rps", Json::num(r.goodput_rps)),
                ("achieved_rps", Json::num(r.achieved_rps)),
                (
                    "latency_s",
                    r.latency.as_ref().map_or(Json::Null, latency_json),
                ),
                ("stages", r.stages.as_ref().map_or(Json::Null, stage_json)),
                ("engine", engine_window_json(&r.engine)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::str("serving_overload")),
        (
            "config",
            Json::obj(vec![
                ("model", Json::str(ecfg.model.name())),
                ("adapter", Json::str(ecfg.adapter.name())),
                ("rank", Json::num(ecfg.rank as f64)),
                ("num_tasks", Json::num(ecfg.num_tasks as f64)),
                ("max_batch", Json::num(ecfg.max_batch as f64)),
                ("workers", Json::num(ecfg.workers as f64)),
                ("queue_capacity", Json::num(ecfg.queue_capacity as f64)),
                ("seed", Json::num(cfg.capacity.seed as f64)),
                ("capacity_clients", Json::num(cfg.capacity.clients as f64)),
                (
                    "capacity_requests_per_client",
                    Json::num(cfg.capacity.requests_per_client as f64),
                ),
                ("requests_per_level", Json::num(cfg.requests_per_level as f64)),
                ("deadline_ms", Json::num(cfg.deadline.as_secs_f64() * 1e3)),
                ("priority", Json::num(cfg.priority as f64)),
            ]),
        ),
        (
            "capacity",
            Json::obj(vec![
                ("throughput_rps", Json::num(report.capacity.throughput_rps)),
                ("requests", Json::num(report.capacity.total_requests as f64)),
                ("latency_s", latency_json(&report.capacity.latency)),
                (
                    "stages",
                    report.capacity.stages.as_ref().map_or(Json::Null, stage_json),
                ),
                ("engine", engine_window_json(&report.capacity.engine)),
            ]),
        ),
        ("levels", Json::Arr(levels)),
    ])
}

/// One level of the resilience comparison: the faulted run's self-healing
/// counters next to its goodput, and the ratio against the fault-free twin.
fn resilience_level_json(mult: f64, faulted: &OpenLoopReport, baseline: &OpenLoopReport) -> Json {
    let overhead = if baseline.goodput_rps > 0.0 {
        faulted.goodput_rps / baseline.goodput_rps
    } else {
        0.0
    };
    Json::obj(vec![
        ("mult", Json::num(mult)),
        ("goodput_rps_faulted", Json::num(faulted.goodput_rps)),
        ("goodput_rps_baseline", Json::num(baseline.goodput_rps)),
        // Goodput retained under faults, 1.0 = free self-healing.
        ("goodput_retention", Json::num(overhead)),
        ("ok", Json::num(faulted.ok as f64)),
        ("errors", Json::num(faulted.errors as f64)),
        ("shed_expired", Json::num(faulted.expired as f64)),
        ("dropped", Json::num(faulted.dropped as f64)),
        ("worker_restarts", Json::num(faulted.engine.worker_restarts as f64)),
        ("quarantined", Json::num(faulted.engine.quarantined as f64)),
        ("requeued", Json::num(faulted.engine.requeued as f64)),
        (
            "latency_s_faulted",
            faulted.latency.as_ref().map_or(Json::Null, latency_json),
        ),
        (
            "latency_s_baseline",
            baseline.latency.as_ref().map_or(Json::Null, latency_json),
        ),
        ("stages_faulted", faulted.stages.as_ref().map_or(Json::Null, stage_json)),
    ])
}

/// Assemble the `BENCH_pr8.json` document: two overload sweeps — one with
/// the fault plan armed, one fault-free twin over the same engine config
/// and seeds — compared level by level. `goodput_retention` is the
/// resilience overhead: how much goodput supervision, requeueing, and
/// quarantine preserve while faults are firing.
pub fn resilience_report_json(
    engine: &ServingEngine,
    cfg: &OverloadConfig,
    fault_spec: &str,
    faulted: &OverloadReport,
    baseline: &OverloadReport,
) -> Json {
    let ecfg = engine.config();
    let levels = faulted
        .levels
        .iter()
        .zip(&baseline.levels)
        .map(|((mult, f), (_, b))| resilience_level_json(*mult, f, b))
        .collect();
    Json::obj(vec![
        ("bench", Json::str("serving_resilience")),
        (
            "config",
            Json::obj(vec![
                ("model", Json::str(ecfg.model.name())),
                ("adapter", Json::str(ecfg.adapter.name())),
                ("rank", Json::num(ecfg.rank as f64)),
                ("num_tasks", Json::num(ecfg.num_tasks as f64)),
                ("max_batch", Json::num(ecfg.max_batch as f64)),
                ("workers", Json::num(ecfg.workers as f64)),
                ("queue_capacity", Json::num(ecfg.queue_capacity as f64)),
                ("seed", Json::num(cfg.capacity.seed as f64)),
                ("requests_per_level", Json::num(cfg.requests_per_level as f64)),
                ("deadline_ms", Json::num(cfg.deadline.as_secs_f64() * 1e3)),
                ("faults", Json::str(fault_spec)),
            ]),
        ),
        (
            "capacity",
            Json::obj(vec![
                ("throughput_rps_faulted", Json::num(faulted.capacity.throughput_rps)),
                ("throughput_rps_baseline", Json::num(baseline.capacity.throughput_rps)),
                ("errors", Json::num(faulted.capacity.errors as f64)),
                ("engine_faulted", engine_window_json(&faulted.capacity.engine)),
            ]),
        ),
        ("levels", Json::Arr(levels)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_stream_is_deterministic_and_respects_the_mix() {
        let cfg = LoadGenConfig {
            seed: 11,
            task_mix: vec![1.0, 0.0, 3.0],
            ..Default::default()
        };
        let a = request_stream(&cfg, 3, 8, 64, 0, 50);
        let b = request_stream(&cfg, 3, 8, 64, 0, 50);
        assert_eq!(a, b, "same (seed, client) must replay the same stream");
        let other = request_stream(&cfg, 3, 8, 64, 1, 50);
        assert_ne!(a, other, "clients must draw distinct streams");
        // Zero-weight tasks never appear; tokens stay in [1, vocab).
        for (task, tokens) in &a {
            assert_ne!(*task, 1, "zero-weight task sampled");
            assert!(tokens.iter().all(|&t| t >= 1 && t < 64));
            assert_eq!(tokens.len(), 8);
        }
        // The heavier task dominates.
        let t2 = a.iter().filter(|(t, _)| *t == 2).count();
        assert!(t2 > 25, "weight-3 task drew only {t2}/50");
    }

    #[test]
    fn single_token_vocab_is_all_pad_not_a_panic() {
        // vocab == 1 means the pad id is the whole vocabulary. The old code
        // called uniform_usize(vocab - 1) == uniform_usize(0) here and
        // panicked on the empty range; the contract is an all-pad request.
        let mut rng = Pcg64::new(3);
        let tokens = request_tokens(&mut rng, 6, 1);
        assert_eq!(tokens, vec![0; 6]);
        // A full stream over a degenerate vocab also survives.
        let cfg = LoadGenConfig { seed: 5, ..Default::default() };
        for (task, tokens) in request_stream(&cfg, 2, 4, 1, 0, 10) {
            assert!(task < 2);
            assert_eq!(tokens, vec![0; 4]);
        }
    }

    #[test]
    #[should_panic(expected = "task mix length")]
    fn wrong_mix_length_is_rejected() {
        let cfg = LoadGenConfig { task_mix: vec![1.0], ..Default::default() };
        let _ = request_stream(&cfg, 3, 8, 64, 0, 1);
    }

    #[test]
    fn stage_breakdown_splits_the_lifecycle() {
        // Two requests: [admit, batch, start, end, done] µs rows.
        let rows = [[0u64, 10, 30, 70, 150], [100, 120, 140, 180, 260]];
        let b = StageBreakdown::from_stamp_rows(&rows).unwrap();
        assert!((b.queue_wait.mean - 15e-6).abs() < 1e-12, "{}", b.queue_wait.mean);
        assert!((b.batch_wait.mean - 20e-6).abs() < 1e-12, "{}", b.batch_wait.mean);
        assert!((b.compute.mean - 40e-6).abs() < 1e-12, "{}", b.compute.mean);
        assert!((b.respond.mean - 80e-6).abs() < 1e-12, "{}", b.respond.mean);
        assert!(StageBreakdown::from_stamp_rows(&[]).is_none(), "no rows, no breakdown");
    }
}
