//! Dynamic same-task batching over the admission queue.
//!
//! A worker's [`BatchPolicy::next_batch`] blocks for the first available
//! request, which pins the batch's task, then coalesces further same-task
//! requests until the batch is full (`max_batch`) or the `deadline` tick
//! since the first pop elapses. Mixed-task traffic never stalls: requests
//! of *other* tasks stay queued for the next worker (or the next call),
//! and workers waiting out a deadline release the queue lock, so admission
//! and other workers' pops proceed concurrently.
//!
//! Batching is **transparent** to clients: every row of the padded serving
//! batch depends only on its own tokens (see `runtime`'s `serve_step`), so
//! a response's bits are independent of which requests happened to share
//! its batch — the timing-dependent coalescing below never shows up in
//! results, only in the batch-size histogram.

use super::request::{AdmissionQueue, Pending};
use std::time::{Duration, Instant};

/// Dynamic-batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Hard batch-size cap (= the bound eval spec's batch dimension).
    pub max_batch: usize,
    /// How long a partially-filled batch waits for same-task stragglers
    /// after its first request was popped. Zero = never wait (greedy).
    pub deadline: Duration,
}

impl BatchPolicy {
    /// Pop the next batch: blocks for the first request, coalesces same-task
    /// arrivals up to `max_batch` or the deadline. Returns `None` once the
    /// queue is closed *and* drained — the worker-shutdown signal.
    pub(crate) fn next_batch(&self, q: &AdmissionQueue) -> Option<Vec<Pending>> {
        debug_assert!(self.max_batch >= 1);
        let mut inner = q.inner.lock().unwrap();
        // Phase 1: block for the batch's first request.
        let first = loop {
            if let Some(p) = inner.queue.pop_front() {
                break p;
            }
            if inner.closed {
                return None;
            }
            inner = q.not_empty.wait(inner).unwrap();
        };
        let task = first.req.task;
        let mut batch = Vec::with_capacity(self.max_batch);
        batch.push(first);
        // The pop above freed a slot — wake blocked producers NOW, not
        // after the deadline wait: a parked same-task producer is exactly
        // the straggler the deadline window exists to absorb.
        q.not_full.notify_all();
        // Phase 2: coalesce same-task requests, waiting out the deadline
        // when the batch is short. Each pass drains every same-task entry
        // currently queued (other tasks are left in admission order).
        let t0 = Instant::now();
        loop {
            let before = batch.len();
            let mut i = 0;
            while batch.len() < self.max_batch && i < inner.queue.len() {
                if inner.queue[i].req.task == task {
                    // remove(i) preserves the relative order of the rest.
                    batch.push(inner.queue.remove(i).expect("index in range"));
                } else {
                    i += 1;
                }
            }
            if batch.len() > before {
                // More slots freed; unpark producers before (possibly)
                // sleeping on the deadline.
                q.not_full.notify_all();
            }
            if batch.len() >= self.max_batch || inner.closed {
                break;
            }
            let waited = t0.elapsed();
            if waited >= self.deadline {
                break;
            }
            let (guard, _timeout) = q
                .not_empty
                .wait_timeout(inner, self.deadline - waited)
                .unwrap();
            inner = guard;
            // Loop: drain whatever arrived, then re-check the deadline.
        }
        drop(inner);
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::{response_channel, Request};
    use std::sync::mpsc::Receiver;
    use std::sync::Arc;

    fn push(q: &AdmissionQueue, id: u64, task: usize) -> Receiver<super::super::Response> {
        let (tx, rx) = response_channel();
        q.submit(Pending {
            req: Request { id, task, tokens: vec![1] },
            tx,
            enqueued: Instant::now(),
        })
        .unwrap();
        rx
    }

    #[test]
    fn coalesces_same_task_and_leaves_others_queued() {
        let q = AdmissionQueue::new(16);
        let _rxs: Vec<_> = [(0u64, 0usize), (1, 1), (2, 0), (3, 0), (4, 1)]
            .iter()
            .map(|&(id, t)| push(&q, id, t))
            .collect();
        let policy = BatchPolicy { max_batch: 8, deadline: Duration::ZERO };
        let b0 = policy.next_batch(&q).unwrap();
        assert_eq!(
            b0.iter().map(|p| p.req.id).collect::<Vec<_>>(),
            vec![0, 2, 3],
            "first batch takes every queued task-0 request"
        );
        let b1 = policy.next_batch(&q).unwrap();
        assert_eq!(b1.iter().map(|p| p.req.id).collect::<Vec<_>>(), vec![1, 4]);
        assert!(q.is_empty());
    }

    #[test]
    fn max_batch_caps_a_burst() {
        let q = AdmissionQueue::new(16);
        let _rxs: Vec<_> = (0..5).map(|id| push(&q, id, 7)).collect();
        let policy = BatchPolicy { max_batch: 2, deadline: Duration::ZERO };
        let sizes: Vec<usize> = (0..3)
            .map(|_| policy.next_batch(&q).unwrap().len())
            .collect();
        assert_eq!(sizes, vec![2, 2, 1]);
    }

    #[test]
    fn deadline_picks_up_late_same_task_arrivals() {
        let q = Arc::new(AdmissionQueue::new(16));
        let _rx0 = push(&q, 0, 3);
        let q2 = Arc::clone(&q);
        let feeder = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            push(&q2, 1, 3)
        });
        let policy = BatchPolicy { max_batch: 4, deadline: Duration::from_millis(300) };
        let b = policy.next_batch(&q).unwrap();
        let _rx1 = feeder.join().unwrap();
        assert_eq!(
            b.iter().map(|p| p.req.id).collect::<Vec<_>>(),
            vec![0, 1],
            "the deadline window must absorb the late arrival"
        );
    }

    #[test]
    fn closed_and_drained_queue_ends_the_worker_loop() {
        let q = AdmissionQueue::new(4);
        let _rx = push(&q, 0, 0);
        q.close();
        let policy = BatchPolicy { max_batch: 4, deadline: Duration::from_millis(50) };
        // The admitted request still comes out (no deadline wait once
        // closed), then the loop signal.
        let b = policy.next_batch(&q).unwrap();
        assert_eq!(b.len(), 1);
        assert!(policy.next_batch(&q).is_none());
    }
}
