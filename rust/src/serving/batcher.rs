//! Dynamic same-task batching with deadline-aware ordering and shedding.
//!
//! A worker's [`BatchPolicy::next_batch`] first **sheds** every queued
//! request whose deadline has already passed (no compute is spent on dead
//! work — the worker answers them with an explicit expired status), then
//! blocks for the most *urgent* runnable request — ordered by priority
//! class, then earliest deadline, then admission order
//! ([`Pending::cmp_urgency`]) — which pins the batch's task. It then
//! coalesces further same-task requests *in urgency order* until the batch
//! is full (`max_batch`) or the `deadline` tick since the first pop
//! elapses. Mixed-task traffic never stalls: requests of *other* tasks
//! stay queued for the next worker (or the next call), and workers waiting
//! out a tick release the queue lock, so admission and other workers' pops
//! proceed concurrently.
//!
//! Under overload this is EDF within a priority class: the requests most
//! likely to still meet their deadlines run first, and the ones that
//! cannot are shed at the queue, which is what keeps goodput near the
//! saturation throughput instead of collapsing (`BENCH_pr6.json`).
//!
//! Batching is **transparent** to clients: every row of the padded serving
//! batch depends only on its own tokens (see `runtime`'s `serve_step`), so
//! a response's bits are independent of which requests happened to share
//! its batch — the timing-dependent coalescing below never shows up in
//! results, only in the batch-size histogram and in *which* requests get
//! shed under saturation.

use super::request::{AdmissionQueue, Pending};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Dynamic-batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Hard batch-size cap (= the bound eval spec's batch dimension).
    pub max_batch: usize,
    /// How long a partially-filled batch waits for same-task stragglers
    /// after its first request was popped. Zero = never wait (greedy).
    pub deadline: Duration,
}

/// What one `next_batch` call drained: requests to execute (all one task,
/// urgency-ordered) and requests shed because their deadline had passed.
/// `run` may be empty when everything drained this tick was already dead.
pub(crate) struct DrainedBatch {
    pub run: Vec<Pending>,
    pub shed: Vec<Pending>,
}

/// Remove every expired request from `queue` into `shed`, preserving the
/// relative order of survivors. Returns how many were shed.
fn shed_expired(queue: &mut VecDeque<Pending>, shed: &mut Vec<Pending>, now: Instant) -> usize {
    let before = shed.len();
    let mut i = 0;
    while i < queue.len() {
        if queue[i].expired_at(now) {
            shed.push(queue.remove(i).expect("index in range"));
        } else {
            i += 1;
        }
    }
    shed.len() - before
}

/// Index of the most urgent request (None on an empty queue).
fn most_urgent(queue: &VecDeque<Pending>) -> Option<usize> {
    let mut best: Option<usize> = None;
    for i in 0..queue.len() {
        match best {
            None => best = Some(i),
            Some(b) => {
                if queue[i].cmp_urgency(&queue[b]).is_lt() {
                    best = Some(i);
                }
            }
        }
    }
    best
}

/// Index of the most urgent coalescible request of `task` (None if no such
/// request). Solo-flagged requests (quarantine retries) never coalesce —
/// they must run in a batch of one, so a poisoned request can't take
/// healthy batch-mates down with it.
fn most_urgent_of_task(queue: &VecDeque<Pending>, task: usize) -> Option<usize> {
    let mut best: Option<usize> = None;
    for i in 0..queue.len() {
        if queue[i].req.task != task || queue[i].solo {
            continue;
        }
        match best {
            None => best = Some(i),
            Some(b) => {
                if queue[i].cmp_urgency(&queue[b]).is_lt() {
                    best = Some(i);
                }
            }
        }
    }
    best
}

impl BatchPolicy {
    /// Drain the next batch: sheds expired requests, blocks for the most
    /// urgent runnable one, coalesces same-task arrivals in urgency order
    /// up to `max_batch` or the tick deadline. Returns `None` once the
    /// queue is closed *and* drained — the worker-shutdown signal.
    pub(crate) fn next_batch(&self, q: &AdmissionQueue) -> Option<DrainedBatch> {
        debug_assert!(self.max_batch >= 1);
        let mut inner = q.inner.lock().unwrap();
        let mut shed: Vec<Pending> = Vec::new();
        // Phase 1: block for the batch's first (most urgent) live request,
        // shedding dead ones as they are encountered. If a pass sheds
        // something but finds nothing runnable, hand the sheds back now so
        // their clients get answered promptly instead of waiting out an
        // arrival.
        let first = loop {
            let now = Instant::now();
            if shed_expired(&mut inner.queue, &mut shed, now) > 0 {
                q.not_full.notify_all();
            }
            if let Some(i) = most_urgent(&inner.queue) {
                break inner.queue.remove(i).expect("index in range");
            }
            if !shed.is_empty() {
                drop(inner);
                return Some(DrainedBatch { run: Vec::new(), shed });
            }
            if inner.closed {
                return None;
            }
            inner = q.not_empty.wait(inner).unwrap();
        };
        let task = first.req.task;
        let solo = first.solo;
        let mut batch = Vec::with_capacity(self.max_batch);
        batch.push(first);
        // The pop above freed a slot — wake blocked producers NOW, not
        // after the tick wait: a parked same-task producer is exactly
        // the straggler the tick window exists to absorb.
        q.not_full.notify_all();
        // A solo (quarantine-retry) request runs alone: no coalescing, no
        // tick wait.
        if solo {
            drop(inner);
            return Some(DrainedBatch { run: batch, shed });
        }
        // Phase 2: coalesce same-task requests in urgency order, waiting
        // out the tick when the batch is short. Each pass sheds anything
        // that expired during the wait (any task — dead work is dead work)
        // and extracts the most urgent same-task survivors.
        let t0 = Instant::now();
        loop {
            let before = batch.len() + shed.len();
            let now = Instant::now();
            shed_expired(&mut inner.queue, &mut shed, now);
            while batch.len() < self.max_batch {
                match most_urgent_of_task(&inner.queue, task) {
                    Some(i) => batch.push(inner.queue.remove(i).expect("index in range")),
                    None => break,
                }
            }
            if batch.len() + shed.len() > before {
                // More slots freed; unpark producers before (possibly)
                // sleeping on the tick.
                q.not_full.notify_all();
            }
            if batch.len() >= self.max_batch || inner.closed {
                break;
            }
            let waited = t0.elapsed();
            if waited >= self.deadline {
                break;
            }
            let (guard, _timeout) = q
                .not_empty
                .wait_timeout(inner, self.deadline - waited)
                .unwrap();
            inner = guard;
            // Loop: drain whatever arrived, then re-check the tick.
        }
        drop(inner);
        Some(DrainedBatch { run: batch, shed })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::{response_channel, Request};
    use std::sync::mpsc::Receiver;
    use std::sync::Arc;

    fn push_with(
        q: &AdmissionQueue,
        id: u64,
        task: usize,
        priority: u8,
        deadline: Option<Duration>,
    ) -> Receiver<super::super::Response> {
        let (tx, rx) = response_channel();
        let now = Instant::now();
        q.submit(Pending {
            req: Request { id, task, tokens: vec![1], priority },
            tx,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            panics: 0,
            solo: false,
            admit_us: 0,
            batch_us: 0,
        })
        .unwrap();
        rx
    }

    fn push_solo(q: &AdmissionQueue, id: u64, task: usize) -> Receiver<super::super::Response> {
        let (tx, rx) = response_channel();
        q.submit(Pending {
            req: Request { id, task, tokens: vec![1], priority: 0 },
            tx,
            enqueued: Instant::now(),
            deadline: None,
            panics: 2,
            solo: true,
            admit_us: 0,
            batch_us: 0,
        })
        .unwrap();
        rx
    }

    fn push(q: &AdmissionQueue, id: u64, task: usize) -> Receiver<super::super::Response> {
        push_with(q, id, task, 0, None)
    }

    fn ids(ps: &[Pending]) -> Vec<u64> {
        ps.iter().map(|p| p.req.id).collect()
    }

    #[test]
    fn coalesces_same_task_and_leaves_others_queued() {
        let q = AdmissionQueue::new(16);
        let _rxs: Vec<_> = [(0u64, 0usize), (1, 1), (2, 0), (3, 0), (4, 1)]
            .iter()
            .map(|&(id, t)| push(&q, id, t))
            .collect();
        let policy = BatchPolicy { max_batch: 8, deadline: Duration::ZERO };
        let b0 = policy.next_batch(&q).unwrap();
        assert_eq!(ids(&b0.run), vec![0, 2, 3], "first batch takes every queued task-0 request");
        assert!(b0.shed.is_empty());
        let b1 = policy.next_batch(&q).unwrap();
        assert_eq!(ids(&b1.run), vec![1, 4]);
        assert!(q.is_empty());
    }

    #[test]
    fn max_batch_caps_a_burst() {
        let q = AdmissionQueue::new(16);
        let _rxs: Vec<_> = (0..5).map(|id| push(&q, id, 7)).collect();
        let policy = BatchPolicy { max_batch: 2, deadline: Duration::ZERO };
        let sizes: Vec<usize> = (0..3)
            .map(|_| policy.next_batch(&q).unwrap().run.len())
            .collect();
        assert_eq!(sizes, vec![2, 2, 1]);
    }

    #[test]
    fn deadline_picks_up_late_same_task_arrivals() {
        let q = Arc::new(AdmissionQueue::new(16));
        let _rx0 = push(&q, 0, 3);
        let q2 = Arc::clone(&q);
        let feeder = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            push(&q2, 1, 3)
        });
        let policy = BatchPolicy { max_batch: 4, deadline: Duration::from_millis(300) };
        let b = policy.next_batch(&q).unwrap();
        let _rx1 = feeder.join().unwrap();
        assert_eq!(ids(&b.run), vec![0, 1], "the tick window must absorb the late arrival");
    }

    #[test]
    fn closed_and_drained_queue_ends_the_worker_loop() {
        let q = AdmissionQueue::new(4);
        let _rx = push(&q, 0, 0);
        q.close();
        let policy = BatchPolicy { max_batch: 4, deadline: Duration::from_millis(50) };
        // The admitted request still comes out (no tick wait once closed),
        // then the loop signal.
        let b = policy.next_batch(&q).unwrap();
        assert_eq!(b.run.len(), 1);
        assert!(policy.next_batch(&q).is_none());
    }

    #[test]
    fn edf_orders_the_batch_and_picks_its_members() {
        let q = AdmissionQueue::new(16);
        // Same task, admitted in id order with shuffled deadlines.
        let _r0 = push_with(&q, 0, 2, 0, Some(Duration::from_millis(500)));
        let _r1 = push_with(&q, 1, 2, 0, Some(Duration::from_millis(100)));
        let _r2 = push_with(&q, 2, 2, 0, None);
        let _r3 = push_with(&q, 3, 2, 0, Some(Duration::from_millis(300)));
        let policy = BatchPolicy { max_batch: 3, deadline: Duration::ZERO };
        let b = policy.next_batch(&q).unwrap();
        assert_eq!(
            ids(&b.run),
            vec![1, 3, 0],
            "earliest deadlines fill the capped batch; deadline-free waits"
        );
        let b2 = policy.next_batch(&q).unwrap();
        assert_eq!(ids(&b2.run), vec![2]);
    }

    #[test]
    fn priority_class_dominates_deadlines_and_picks_the_task() {
        let q = AdmissionQueue::new(16);
        // An earlier-deadline class-1 request on task 0 vs a later-deadline
        // class-0 request on task 1: the class-0 one pins the batch's task.
        // (Both deadlines are far enough out never to expire in-test.)
        let _r0 = push_with(&q, 0, 0, 1, Some(Duration::from_secs(2)));
        let _r1 = push_with(&q, 1, 1, 0, Some(Duration::from_secs(5)));
        let _r2 = push_with(&q, 2, 1, 0, None);
        let policy = BatchPolicy { max_batch: 4, deadline: Duration::ZERO };
        let b = policy.next_batch(&q).unwrap();
        assert_eq!(ids(&b.run), vec![1, 2], "priority class pins the batch task");
        let b2 = policy.next_batch(&q).unwrap();
        assert_eq!(ids(&b2.run), vec![0]);
    }

    #[test]
    fn expired_requests_are_shed_not_run() {
        let q = AdmissionQueue::new(16);
        // Admitted already-expired (zero relative deadline): by the time a
        // worker drains, now >= deadline deterministically.
        let _r0 = push_with(&q, 0, 0, 0, Some(Duration::ZERO));
        let _r1 = push_with(&q, 1, 0, 0, None);
        let _r2 = push_with(&q, 2, 1, 0, Some(Duration::ZERO));
        let policy = BatchPolicy { max_batch: 4, deadline: Duration::ZERO };
        let b = policy.next_batch(&q).unwrap();
        assert_eq!(ids(&b.run), vec![1], "live request runs");
        let mut shed = ids(&b.shed);
        shed.sort_unstable();
        assert_eq!(shed, vec![0, 2], "dead requests shed across tasks");
        assert!(q.is_empty());
    }

    #[test]
    fn solo_requests_never_coalesce() {
        let q = AdmissionQueue::new(16);
        // A solo (quarantine-retry) request surrounded by same-task
        // traffic: it runs in a batch of one, and the healthy requests
        // batch together without it.
        let _r0 = push_solo(&q, 0, 2);
        let _r1 = push(&q, 1, 2);
        let _r2 = push(&q, 2, 2);
        let policy = BatchPolicy { max_batch: 8, deadline: Duration::ZERO };
        let b0 = policy.next_batch(&q).unwrap();
        assert_eq!(ids(&b0.run), vec![0], "the solo request runs alone");
        let b1 = policy.next_batch(&q).unwrap();
        assert_eq!(ids(&b1.run), vec![1, 2], "healthy requests still batch");
        // And when a healthy request pins the batch first, the solo one is
        // skipped by coalescing.
        let _r3 = push(&q, 3, 4);
        let _r4 = push_solo(&q, 4, 4);
        let _r5 = push(&q, 5, 4);
        let b2 = policy.next_batch(&q).unwrap();
        assert_eq!(ids(&b2.run), vec![3, 5], "coalescing skips the solo request");
        let b3 = policy.next_batch(&q).unwrap();
        assert_eq!(ids(&b3.run), vec![4]);
    }

    #[test]
    fn all_expired_drain_returns_an_empty_run() {
        let q = AdmissionQueue::new(16);
        let _r0 = push_with(&q, 0, 0, 0, Some(Duration::ZERO));
        let _r1 = push_with(&q, 1, 3, 0, Some(Duration::ZERO));
        let policy = BatchPolicy { max_batch: 4, deadline: Duration::from_millis(200) };
        let b = policy.next_batch(&q).unwrap();
        assert!(b.run.is_empty(), "nothing runnable");
        assert_eq!(b.shed.len(), 2, "both dead requests handed back immediately");
        // And the worker loop signal still works after.
        q.close();
        assert!(policy.next_batch(&q).is_none());
    }
}
