//! Property-testing helper (the offline registry has no `proptest`).
//!
//! `prop_check` runs a closure over N seeded random cases; on failure it
//! reports the failing seed so the case can be replayed deterministically:
//! `prop_check` derives each case's RNG from (suite seed, case index), so
//! re-running the named test reproduces the exact failure.

use crate::util::rng::Pcg64;

/// Run `cases` random property checks. `f` gets a per-case RNG and the case
/// index and returns `Err(msg)` to signal a violation.
pub fn prop_check<F>(name: &str, cases: usize, f: F)
where
    F: Fn(&mut Pcg64, usize) -> Result<(), String>,
{
    let suite_seed: u64 = 0x6d65_7461_7474; // "metatt"
    for case in 0..cases {
        let mut rng = Pcg64::with_stream(suite_seed, case as u64 + 1);
        if let Err(msg) = f(&mut rng, case) {
            panic!("property '{name}' violated at case {case}: {msg}");
        }
    }
}

/// Random shape helper: each dim uniform in [lo, hi].
pub fn rand_shape(rng: &mut Pcg64, ndim: usize, lo: usize, hi: usize) -> Vec<usize> {
    (0..ndim).map(|_| lo + rng.uniform_usize(hi - lo + 1)).collect()
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "{ctx}: element {i} differs: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_check_passes_valid_property() {
        prop_check("square nonneg", 50, |rng, _| {
            let x = rng.normal();
            if x * x >= 0.0 {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn prop_check_reports_failures() {
        prop_check("always fails", 3, |_, _| Err("boom".into()));
    }

    #[test]
    fn rand_shape_in_bounds() {
        let mut rng = Pcg64::new(1);
        for _ in 0..100 {
            let s = rand_shape(&mut rng, 3, 2, 9);
            assert!(s.iter().all(|&d| (2..=9).contains(&d)));
        }
    }
}
