//! Experiment configuration: model presets, training hyper-parameters, and
//! TOML-file loading for the launcher.
//!
//! Model presets: the compute-bearing experiments run on `tiny` / `small`
//! encoders (CPU-feasible, see DESIGN.md §3); the analytic complexity
//! experiments use true RoBERTa dimensions via `adapters::ModelDims`.

use crate::adapters::{AdapterKind, AdapterSpec, ModelDims};
use crate::runtime::BackendKind;
use crate::util::json::Json;
use crate::util::toml;
use std::path::Path;

/// A named model size preset. These must match `MODEL_PRESETS` in
/// `python/compile/model.py` — the manifest records the preset per artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelPreset {
    /// 4 layers, d=64, 4 heads, vocab 512, seq 32 — the experiment-grid
    /// scale (~0.3 M params; every Table-1/Figure run is CPU-feasible).
    Tiny,
    /// 6 layers, d=128, 8 heads, vocab 1024, seq 64 — mid scale (~1.5 M).
    Small,
    /// 12 layers, d=256, 8 heads, vocab 1024, seq 64 — "base-sim", the e2e
    /// example scale (~10 M); the RoBERTa stand-in for CPU runs.
    BaseSim,
}

impl ModelPreset {
    pub fn name(&self) -> &'static str {
        match self {
            ModelPreset::Tiny => "tiny",
            ModelPreset::Small => "small",
            ModelPreset::BaseSim => "base_sim",
        }
    }

    pub fn from_name(s: &str) -> Result<ModelPreset, String> {
        match s {
            "tiny" => Ok(ModelPreset::Tiny),
            "small" => Ok(ModelPreset::Small),
            "base_sim" => Ok(ModelPreset::BaseSim),
            other => Err(format!("unknown model preset '{other}'")),
        }
    }

    /// Structural dims (matrices = Q,V per paper App. A.2; tasks set by the
    /// experiment).
    pub fn dims(&self, tasks: usize) -> ModelDims {
        match self {
            ModelPreset::Tiny => ModelDims {
                hidden: 64,
                layers: 4,
                heads: 4,
                matrices: 2,
                tasks,
                vocab: 512,
                ffn: 256,
                max_seq: 32,
            },
            ModelPreset::Small => ModelDims {
                hidden: 128,
                layers: 6,
                heads: 8,
                matrices: 2,
                tasks,
                vocab: 1024,
                ffn: 512,
                max_seq: 64,
            },
            ModelPreset::BaseSim => ModelDims {
                hidden: 256,
                layers: 12,
                heads: 8,
                matrices: 2,
                tasks,
                vocab: 1024,
                ffn: 1024,
                max_seq: 64,
            },
        }
    }
}

/// Training-loop hyper-parameters (paper Appendix D grids).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch_size: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub warmup_ratio: f32,
    /// Max global gradient norm; 0 disables clipping.
    pub grad_clip: f32,
    pub seed: u64,
    /// Cap on training examples (the paper's MTL protocol caps at 5000).
    pub train_cap: usize,
    pub eval_cap: usize,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig {
            epochs: 20,
            batch_size: 16,
            lr: 1e-3,
            weight_decay: 0.0,
            warmup_ratio: 0.06,
            grad_clip: 3.0,
            seed: 42,
            train_cap: 2_000,
            eval_cap: 500,
        }
    }
}

/// A full experiment description (one run).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub model: ModelPreset,
    pub adapter: AdapterKind,
    pub rank: usize,
    pub alpha: f32,
    pub tasks: Vec<String>,
    pub train: TrainConfig,
    /// Execution backend for config-file-driven runs (`backend = "ref"` in
    /// TOML; the `--backend` CLI flag overrides it). Programmatic callers
    /// pass a constructed backend directly, so the field is informational
    /// for them.
    pub backend: BackendKind,
    /// Worker-thread budget for the reference backend (`[runtime]`'s
    /// `threads` key; the `--threads` CLI flag overrides it). `None` defers
    /// to `METATT_THREADS` / host auto-detection; `0` is rejected at parse
    /// time.
    pub threads: Option<usize>,
}

impl ExperimentConfig {
    pub fn adapter_spec(&self) -> AdapterSpec {
        let dims = self.model.dims(self.tasks.len().max(1));
        AdapterSpec::new(self.adapter, self.rank, self.alpha, dims)
    }

    /// Load from a TOML file (see `configs/*.toml`).
    pub fn from_toml(path: &Path) -> Result<ExperimentConfig, String> {
        let doc = toml::parse_file(path)?;
        Self::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<ExperimentConfig, String> {
        let str_field = |key: &str, default: &str| -> String {
            doc.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
        };
        let model = ModelPreset::from_name(&str_field("model", "tiny"))?;
        let adapter = AdapterKind::from_name(&str_field("adapter", "metatt4d"))?;
        let backend = BackendKind::from_name(&str_field("backend", "ref"))?;
        let rank = doc.get("rank").and_then(|v| v.as_usize()).unwrap_or(8);
        let alpha = doc.get("alpha").and_then(|v| v.as_f64()).unwrap_or(4.0) as f32;
        let threads = match doc.get("runtime").and_then(|r| r.get("threads")) {
            None => None,
            Some(v) => match v.as_usize() {
                Some(0) => {
                    return Err(
                        "[runtime] threads = 0 is invalid: use threads = 1 for \
                         serial execution or remove the key to auto-detect"
                            .to_string(),
                    )
                }
                Some(n) => Some(n),
                None => return Err("[runtime] threads must be a positive integer".to_string()),
            },
        };
        let tasks = match doc.get("tasks").and_then(|v| v.as_arr()) {
            Some(arr) => arr
                .iter()
                .map(|v| v.as_str().map(|s| s.to_string()))
                .collect::<Option<Vec<_>>>()
                .ok_or("tasks must be strings")?,
            None => vec!["mrpc_syn".to_string()],
        };
        let mut train = TrainConfig::default();
        if let Some(t) = doc.get("train") {
            if let Some(v) = t.get("epochs").and_then(|v| v.as_usize()) {
                train.epochs = v;
            }
            if let Some(v) = t.get("batch_size").and_then(|v| v.as_usize()) {
                train.batch_size = v;
            }
            if let Some(v) = t.get("lr").and_then(|v| v.as_f64()) {
                train.lr = v as f32;
            }
            if let Some(v) = t.get("weight_decay").and_then(|v| v.as_f64()) {
                train.weight_decay = v as f32;
            }
            if let Some(v) = t.get("warmup_ratio").and_then(|v| v.as_f64()) {
                train.warmup_ratio = v as f32;
            }
            if let Some(v) = t.get("grad_clip").and_then(|v| v.as_f64()) {
                train.grad_clip = v as f32;
            }
            if let Some(v) = t.get("seed").and_then(|v| v.as_usize()) {
                train.seed = v as u64;
            }
            if let Some(v) = t.get("train_cap").and_then(|v| v.as_usize()) {
                train.train_cap = v;
            }
            if let Some(v) = t.get("eval_cap").and_then(|v| v.as_usize()) {
                train.eval_cap = v;
            }
        }
        Ok(ExperimentConfig { model, adapter, rank, alpha, tasks, train, backend, threads })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::toml;

    #[test]
    fn presets_have_consistent_dims() {
        for p in [ModelPreset::Tiny, ModelPreset::Small, ModelPreset::BaseSim] {
            let d = p.dims(1);
            assert_eq!(d.hidden % d.heads, 0, "{:?}", p);
            assert_eq!(ModelPreset::from_name(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn experiment_config_from_toml() {
        let doc = toml::parse(
            r#"
model = "small"
adapter = "metatt5d"
rank = 16
alpha = 0.5
tasks = ["mrpc_syn", "rte_syn"]

[train]
epochs = 5
batch_size = 32
lr = 0.0005
seed = 2025
"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.model, ModelPreset::Small);
        assert_eq!(cfg.adapter.name(), "metatt5d");
        assert_eq!(cfg.rank, 16);
        assert_eq!(cfg.tasks.len(), 2);
        assert_eq!(cfg.train.epochs, 5);
        assert_eq!(cfg.train.seed, 2025);
        let spec = cfg.adapter_spec();
        assert_eq!(spec.dims.tasks, 2);
        assert!(spec.param_count() > 0);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let doc = toml::parse("model = \"tiny\"").unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.rank, 8);
        assert_eq!(cfg.train.epochs, 20);
        assert_eq!(cfg.tasks, vec!["mrpc_syn"]);
        assert_eq!(cfg.backend, BackendKind::Ref);
    }

    #[test]
    fn runtime_threads_parse_and_reject_zero() {
        let doc = toml::parse("model = \"tiny\"\n[runtime]\nthreads = 4\n").unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.threads, Some(4));
        // Unset: defer to env/auto.
        let doc = toml::parse("model = \"tiny\"").unwrap();
        assert_eq!(ExperimentConfig::from_json(&doc).unwrap().threads, None);
        // threads = 0 must fail with a helpful message, not panic downstream.
        let doc = toml::parse("model = \"tiny\"\n[runtime]\nthreads = 0\n").unwrap();
        let err = ExperimentConfig::from_json(&doc).unwrap_err();
        assert!(err.contains("threads = 1"), "unhelpful: {err}");
    }

    #[test]
    fn backend_field_parses_and_rejects_unknown() {
        let doc = toml::parse("backend = \"pjrt\"").unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.backend, BackendKind::Pjrt);
        let bad = toml::parse("backend = \"tpu\"").unwrap();
        assert!(ExperimentConfig::from_json(&bad).is_err());
    }
}
