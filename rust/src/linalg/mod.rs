//! Linear-algebra substrate: QR and SVD, built from scratch.
//!
//! The DMRG-inspired sweep (paper Algorithm 1) is a sequence of truncated
//! SVDs on merged TT cores. No LAPACK is available in this environment, so
//! we implement:
//!
//! * Householder QR (with thin Q recovery) — used to pre-reduce tall
//!   matrices before the SVD and for TT orthogonalization.
//! * One-sided Jacobi SVD — numerically robust, simple, and fast enough for
//!   the merged-core sizes MetaTT produces (≤ a few hundred on a side).
//! * `truncated_svd` — the `tSVD(M; r)` primitive of Algorithm 1.
//!
//! Merged cores are (r·n) × (n'·r') with r ≤ 64 and n ∈ {L, M, H, T}, so the
//! matrices are small; the boundary merges touch D (≤ 1024) on one side,
//! which the QR pre-reduction shrinks to min(m, n) before Jacobi runs.

use crate::tensor::Tensor;

/// Result of a (possibly truncated) SVD: `a ≈ u · diag(s) · vt`.
#[derive(Clone, Debug)]
pub struct Svd {
    /// m × k, orthonormal columns.
    pub u: Tensor,
    /// k singular values, descending.
    pub s: Vec<f32>,
    /// k × n, orthonormal rows.
    pub vt: Tensor,
}

/// Householder QR of an m×n matrix. Returns (Q thin m×k, R k×n), k=min(m,n).
pub fn qr(a: &Tensor) -> (Tensor, Tensor) {
    let (m, n) = (a.rows(), a.cols());
    let k = m.min(n);
    let mut r = a.clone();
    // Householder vectors stored per reflection.
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(k);
    for j in 0..k {
        // Build the Householder vector for column j below the diagonal.
        let mut norm2 = 0.0f64;
        for i in j..m {
            let x = r.at(i, j) as f64;
            norm2 += x * x;
        }
        let norm = norm2.sqrt() as f32;
        let mut v = vec![0.0f32; m - j];
        if norm > 0.0 {
            let x0 = r.at(j, j);
            let alpha = if x0 >= 0.0 { -norm } else { norm };
            v[0] = x0 - alpha;
            for i in j + 1..m {
                v[i - j] = r.at(i, j);
            }
            let vnorm2: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
            if vnorm2 > 1e-30 {
                // Apply H = I - 2 v v^T / (v^T v) to R[j.., j..].
                for col in j..n {
                    let mut dot = 0.0f64;
                    for i in j..m {
                        dot += v[i - j] as f64 * r.at(i, col) as f64;
                    }
                    let coef = (2.0 * dot / vnorm2) as f32;
                    for i in j..m {
                        let val = r.at(i, col) - coef * v[i - j];
                        r.set(i, col, val);
                    }
                }
            } else {
                v[0] = 0.0;
            }
        }
        vs.push(v);
    }
    // Zero the strictly-lower part of R and clip to k rows.
    let mut r_out = Tensor::zeros(&[k, n]);
    for i in 0..k {
        for j in i..n {
            r_out.set(i, j, r.at(i, j));
        }
    }
    // Recover thin Q by applying reflections to the first k columns of I.
    let mut q = Tensor::eye_rect(m, k);
    for j in (0..k).rev() {
        let v = &vs[j];
        let vnorm2: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
        if vnorm2 <= 1e-30 {
            continue;
        }
        for col in 0..k {
            let mut dot = 0.0f64;
            for i in j..m {
                dot += v[i - j] as f64 * q.at(i, col) as f64;
            }
            let coef = (2.0 * dot / vnorm2) as f32;
            for i in j..m {
                let val = q.at(i, col) - coef * v[i - j];
                q.set(i, col, val);
            }
        }
    }
    (q, r_out)
}

/// Full SVD via one-sided Jacobi, with QR/LQ pre-reduction for rectangular
/// inputs. Returns k = min(m, n) triplets, singular values descending.
pub fn svd(a: &Tensor) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    if m >= n {
        if m > n {
            // Tall: A = Q R, svd(R) = U S Vt, so A = (Q U) S Vt.
            let (q, r) = qr(a);
            let inner = jacobi_svd(&r);
            return Svd { u: q.matmul(&inner.u), s: inner.s, vt: inner.vt };
        }
        jacobi_svd(a)
    } else {
        // Wide: svd(A^T) then swap roles.
        let at = a.transpose();
        let t = svd(&at);
        Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() }
    }
}

/// One-sided Jacobi SVD for m×n with m >= n (square or mildly tall).
fn jacobi_svd(a: &Tensor) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    debug_assert!(m >= n);
    // Work on columns of U = A; rotate pairs until all are orthogonal.
    let mut u = a.clone();
    let mut v = Tensor::eye(n);
    let max_sweeps = 60;
    let eps = 1e-12f64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries for columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let up = u.at(i, p) as f64;
                    let uq = u.at(i, q) as f64;
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation that zeroes the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for i in 0..m {
                    let up = u.at(i, p);
                    let uq = u.at(i, q);
                    u.set(i, p, cf * up - sf * uq);
                    u.set(i, q, sf * up + cf * uq);
                }
                for i in 0..n {
                    let vp = v.at(i, p);
                    let vq = v.at(i, q);
                    v.set(i, p, cf * vp - sf * vq);
                    v.set(i, q, sf * vp + cf * vq);
                }
            }
        }
        if off < 1e-14 {
            break;
        }
    }
    // Column norms are the singular values; normalize U's columns.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sigmas = vec![0.0f32; n];
    for j in 0..n {
        let norm: f64 = (0..m).map(|i| (u.at(i, j) as f64).powi(2)).sum::<f64>().sqrt();
        sigmas[j] = norm as f32;
    }
    order.sort_by(|&a, &b| sigmas[b].partial_cmp(&sigmas[a]).unwrap());
    let mut u_out = Tensor::zeros(&[m, n]);
    let mut vt_out = Tensor::zeros(&[n, n]);
    for (new_j, &old_j) in order.iter().enumerate() {
        let sig = sigmas[old_j];
        let inv = if sig > 1e-30 { 1.0 / sig } else { 0.0 };
        for i in 0..m {
            u_out.set(i, new_j, u.at(i, old_j) * inv);
        }
        for i in 0..n {
            vt_out.set(new_j, i, v.at(i, old_j));
        }
    }
    let s: Vec<f32> = order.iter().map(|&j| sigmas[j]).collect();
    Svd { u: u_out, s, vt: vt_out }
}

/// Truncated SVD: keep at most `rank` leading triplets — `tSVD(M; r)` from
/// Algorithm 1. Also drops trailing numerically-zero singular values so the
/// returned rank never exceeds the matrix's numerical rank.
pub fn truncated_svd(a: &Tensor, rank: usize) -> Svd {
    truncated_svd_with_tail(a, rank).0
}

/// [`truncated_svd`] that also reports the *relative dropped weight*
/// `sqrt(Σ_{k>r} σ_k²) / sqrt(Σ_k σ_k²)` computed directly from the
/// discarded singular values (no cancellation, unlike `‖A‖² - ‖A_k‖²`).
///
/// Perf (EXPERIMENTS.md §Perf L3 iteration 4): when the requested rank is
/// far below min(m, n) — the DMRG regime at RoBERTa-scale boundary merges,
/// e.g. 768×768 truncated to 64 — full Jacobi is O(n³·sweeps) and was the
/// dominant host cost. We switch to a randomized range-finder (Halko-
/// Martinsson-Tropp: Gaussian sketch + 2 power iterations + exact SVD of
/// the (k+8)×n projection), which is exact up to the spectral tail the
/// truncation discards anyway.
pub fn truncated_svd_with_tail(a: &Tensor, rank: usize) -> (Svd, f32) {
    let min_dim = a.rows().min(a.cols());
    let k = rank.max(1);
    if min_dim > 4 * k && min_dim > 96 {
        return randomized_truncated_svd(a, k);
    }
    let full = svd(a);
    let k_max = full.s.len().min(rank.max(1));
    // Drop numerically-zero tail (relative to sigma_0).
    let tol = full.s.first().copied().unwrap_or(0.0) * 1e-7;
    let mut k = k_max;
    while k > 1 && full.s[k - 1] <= tol {
        k -= 1;
    }
    let total: f64 = full.s.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let tail: f64 = full.s[k..].iter().map(|&x| (x as f64) * (x as f64)).sum();
    let dropped = if total > 0.0 { (tail / total).sqrt() as f32 } else { 0.0 };
    (
        Svd {
            u: full.u.cols_slice(0, k),
            s: full.s[..k].to_vec(),
            vt: full.vt.rows_slice(0, k),
        },
        dropped,
    )
}

/// Randomized truncated SVD (Halko-Martinsson-Tropp) for rank ≪ min(m, n).
/// Gaussian sketch of k+8 columns, two power iterations (QR-stabilized),
/// exact Jacobi SVD on the small projected matrix. Deterministic: the test
/// matrix comes from a fixed-seed PCG stream.
fn randomized_truncated_svd(a: &Tensor, k: usize) -> (Svd, f32) {
    let (m, n) = (a.rows(), a.cols());
    let p = (k + 8).min(m.min(n));
    let mut rng = crate::util::rng::Pcg64::with_stream(0x5d5d5d, 0x4a11);
    let omega = Tensor::randn(&[n, p], 1.0, &mut rng);
    let mut y = a.matmul(&omega); // m×p
    for _ in 0..2 {
        let (q, _) = qr(&y);
        let z = a.t_matmul(&q); // n×p
        let (qz, _) = qr(&z);
        y = a.matmul(&qz);
    }
    let (q, _) = qr(&y); // m×p, orthonormal columns
    let b = q.t_matmul(a); // p×n (small)
    let inner = svd(&b);
    // Clip to k and drop the numerically-zero tail.
    let tol = inner.s.first().copied().unwrap_or(0.0) * 1e-7;
    let mut keep = k.min(inner.s.len());
    while keep > 1 && inner.s[keep - 1] <= tol {
        keep -= 1;
    }
    let result = Svd {
        u: q.matmul(&inner.u.cols_slice(0, keep)),
        s: inner.s[..keep].to_vec(),
        vt: inner.vt.rows_slice(0, keep),
    };
    // Dropped weight from energies: ‖A‖² is exact; Σσ² of the kept block is
    // exact on the small matrix. (f64 accumulation throughout.)
    let total: f64 = a.data().iter().map(|&x| (x as f64) * (x as f64)).sum();
    let kept: f64 = result.s.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let dropped = if total > 0.0 {
        ((total - kept).max(0.0) / total).sqrt() as f32
    } else {
        0.0
    };
    (result, dropped)
}

impl Svd {
    /// Reconstruct `u · diag(s) · vt`.
    pub fn reconstruct(&self) -> Tensor {
        let k = self.s.len();
        let mut us = self.u.clone();
        for j in 0..k {
            for i in 0..us.rows() {
                let v = us.at(i, j) * self.s[j];
                us.set(i, j, v);
            }
        }
        us.matmul(&self.vt)
    }

    /// `u` and `s·vt` — the left-to-right DMRG split (Algorithm 1, line 4).
    pub fn split_left_canonical(&self) -> (Tensor, Tensor) {
        let mut svt = self.vt.clone();
        for i in 0..self.s.len() {
            for j in 0..svt.cols() {
                let v = svt.at(i, j) * self.s[i];
                svt.set(i, j, v);
            }
        }
        (self.u.clone(), svt)
    }

    /// `u·s` and `vt` — the right-to-left DMRG split (Algorithm 1, line 9).
    pub fn split_right_canonical(&self) -> (Tensor, Tensor) {
        let mut us = self.u.clone();
        for j in 0..self.s.len() {
            for i in 0..us.rows() {
                let v = us.at(i, j) * self.s[j];
                us.set(i, j, v);
            }
        }
        (us, self.vt.clone())
    }
}

/// Spectral-style error of a rank-k approximation: ‖A - A_k‖_F / ‖A‖_F.
pub fn lowrank_rel_err(a: &Tensor, approx: &Tensor) -> f32 {
    a.sub(approx).fro_norm() / a.fro_norm().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rel_err;
    use crate::util::rng::Pcg64;

    fn assert_orthonormal_cols(q: &Tensor, tol: f32) {
        let gram = q.t_matmul(q);
        let eye = Tensor::eye(q.cols());
        assert!(rel_err(&gram, &eye) < tol, "gram err {}", rel_err(&gram, &eye));
    }

    #[test]
    fn qr_reconstructs_and_q_is_orthonormal() {
        let mut rng = Pcg64::new(1);
        for &(m, n) in &[(5, 5), (12, 4), (30, 7), (4, 9)] {
            let a = Tensor::randn(&[m, n], 1.0, &mut rng);
            let (q, r) = qr(&a);
            assert_eq!(q.shape(), &[m, m.min(n)]);
            assert_eq!(r.shape(), &[m.min(n), n]);
            assert!(rel_err(&q.matmul(&r), &a) < 1e-4, "({m},{n})");
            assert_orthonormal_cols(&q, 1e-4);
            // R upper-triangular
            for i in 0..r.rows() {
                for j in 0..i.min(r.cols()) {
                    assert!(r.at(i, j).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn svd_reconstructs_random_matrices() {
        let mut rng = Pcg64::new(2);
        for &(m, n) in &[(6, 6), (20, 5), (5, 20), (33, 17), (64, 48)] {
            let a = Tensor::randn(&[m, n], 1.0, &mut rng);
            let d = svd(&a);
            assert!(rel_err(&d.reconstruct(), &a) < 1e-4, "({m},{n})");
            assert_orthonormal_cols(&d.u, 1e-4);
            assert_orthonormal_cols(&d.vt.transpose(), 1e-4);
            // descending singular values
            for w in d.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-5);
            }
        }
    }

    #[test]
    fn svd_recovers_known_rank() {
        let mut rng = Pcg64::new(3);
        // Build an exactly rank-3 matrix.
        let u = Tensor::randn(&[24, 3], 1.0, &mut rng);
        let v = Tensor::randn(&[3, 18], 1.0, &mut rng);
        let a = u.matmul(&v);
        let d = svd(&a);
        assert!(d.s[2] > 1e-3);
        assert!(d.s[3] < d.s[0] * 1e-5, "s3={} s0={}", d.s[3], d.s[0]);
    }

    #[test]
    fn truncation_is_best_lowrank_in_frobenius() {
        let mut rng = Pcg64::new(4);
        let a = Tensor::randn(&[16, 12], 1.0, &mut rng);
        let full = svd(&a);
        let k = 4;
        let trunc = truncated_svd(&a, k);
        assert_eq!(trunc.s.len(), k);
        let err = lowrank_rel_err(&a, &trunc.reconstruct());
        // Eckart–Young: error equals the norm of the dropped tail.
        let tail: f32 =
            full.s[k..].iter().map(|&x| x * x).sum::<f32>().sqrt() / a.fro_norm();
        assert!((err - tail).abs() < 1e-4, "err {err} tail {tail}");
    }

    #[test]
    fn truncated_rank_never_exceeds_numerical_rank() {
        let mut rng = Pcg64::new(5);
        let u = Tensor::randn(&[10, 2], 1.0, &mut rng);
        let v = Tensor::randn(&[2, 10], 1.0, &mut rng);
        let a = u.matmul(&v); // rank 2
        let t = truncated_svd(&a, 6);
        assert!(t.s.len() <= 2, "kept {} values", t.s.len());
        assert!(lowrank_rel_err(&a, &t.reconstruct()) < 1e-4);
    }

    #[test]
    fn canonical_splits_multiply_back() {
        let mut rng = Pcg64::new(6);
        let a = Tensor::randn(&[9, 14], 1.0, &mut rng);
        let t = truncated_svd(&a, 5);
        let (l1, r1) = t.split_left_canonical();
        let (l2, r2) = t.split_right_canonical();
        assert!(rel_err(&l1.matmul(&r1), &t.reconstruct()) < 1e-4);
        assert!(rel_err(&l2.matmul(&r2), &t.reconstruct()) < 1e-4);
        assert_orthonormal_cols(&l1, 1e-4);
        assert_orthonormal_cols(&r2.transpose(), 1e-4);
    }

    #[test]
    fn svd_handles_degenerate_inputs() {
        let z = Tensor::zeros(&[4, 3]);
        let d = svd(&z);
        assert!(d.s.iter().all(|&s| s == 0.0));
        let one = Tensor::from_vec(&[1, 1], vec![3.0]);
        let d1 = svd(&one);
        assert!((d1.s[0] - 3.0).abs() < 1e-6);
    }
}

#[cfg(test)]
mod randomized_tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg64;

    #[test]
    fn randomized_matches_exact_on_lowrank_data() {
        let mut rng = Pcg64::new(1);
        // 200x180 matrix of true rank 12, truncate to 12: near-exact.
        let u = Tensor::randn(&[200, 12], 1.0, &mut rng);
        let v = Tensor::randn(&[12, 180], 1.0, &mut rng);
        let a = u.matmul(&v);
        let (t, dropped) = truncated_svd_with_tail(&a, 12);
        assert!(t.s.len() <= 12);
        let err = lowrank_rel_err(&a, &t.reconstruct());
        assert!(err < 1e-3, "err {err}");
        assert!(dropped < 1e-3, "dropped {dropped}");
    }

    #[test]
    fn randomized_close_to_optimal_on_full_rank_data() {
        let mut rng = Pcg64::new(2);
        let a = Tensor::randn(&[160, 160], 1.0, &mut rng);
        let k = 16;
        // exact truncation via full Jacobi (bypass the size heuristic)
        let full = svd(&a);
        let opt_tail: f32 =
            (full.s[k..].iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
                / full.s.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
            .sqrt() as f32;
        let (t, dropped) = truncated_svd_with_tail(&a, k);
        let err = lowrank_rel_err(&a, &t.reconstruct());
        // Randomized is near-optimal: within 5% of the Eckart-Young error.
        assert!(err <= opt_tail * 1.05 + 1e-4, "err {err} vs opt {opt_tail}");
        assert!((dropped - opt_tail).abs() < 0.05, "dropped {dropped} vs {opt_tail}");
    }

    #[test]
    fn randomized_is_deterministic() {
        let mut rng = Pcg64::new(3);
        let a = Tensor::randn(&[150, 150], 1.0, &mut rng);
        let (t1, d1) = truncated_svd_with_tail(&a, 10);
        let (t2, d2) = truncated_svd_with_tail(&a, 10);
        assert_eq!(t1.s, t2.s);
        assert_eq!(d1, d2);
        assert_eq!(t1.u, t2.u);
    }
}
