//! Regression tests for the boundary-core identity init (the rank-collapse
//! bug): with `ze-id-id-id`, the *effective* trained subspace must scale
//! with r, i.e. the right boundary must expose every bond channel.

use super::*;
use crate::tensor::Tensor;
use crate::tt::meta::{MetaTt, MetaTtDims, MetaTtKind};
use crate::util::rng::Pcg64;

fn dims() -> MetaTtDims {
    MetaTtDims { d_in: 16, d_out: 16, layers: 3, matrices: 2, heads: 4, tasks: 1 }
}

#[test]
fn right_boundary_identity_is_rect_eye_in_matrix_view() {
    let mut rng = Pcg64::new(1);
    let tt = MetaTt::new_default(MetaTtKind::FourD, dims(), 4, 1.0, &mut rng);
    let exported = tt.export_cores();
    let g4 = &exported[3]; // (r, d_out)
    assert_eq!(g4.shape(), &[4, 16]);
    for a in 0..4 {
        for j in 0..16 {
            let want = if a == j { 1.0 } else { 0.0 };
            assert_eq!(g4.at(a, j), want, "g4[{a},{j}]");
        }
    }
}

#[test]
fn gradient_channel_is_full_rank_not_rank1() {
    // With G4 = eye_rect(r, D): (mid · G4) maps bond j -> output dim j for
    // j < r, so dY/dG1 has r independent columns. The old (buggy) slice-
    // identity boundary made (mid·G4) rank 1, so every rank trained the
    // same function.
    let mut rng = Pcg64::new(2);
    let tt = MetaTt::new_default(MetaTtKind::FourD, dims(), 4, 1.0, &mut rng);
    let mid = tt.chain.middle_product(1, 2, &[0, 0]);
    let g4 = tt.chain.core(3).reshape(&[4, 16]);
    let right = mid.matmul(&g4); // r x D
    let svd = crate::linalg::svd(&right);
    let numerical_rank = svd.s.iter().filter(|&&s| s > 1e-5).count();
    assert_eq!(numerical_rank, 4, "right factor must expose all r channels");
}

#[test]
fn five_d_boundary_also_full_channel() {
    let mut rng = Pcg64::new(3);
    let tt = MetaTt::new_default(MetaTtKind::FiveD, dims(), 3, 1.0, &mut rng);
    let g5 = tt.chain.core(4).reshape(&[3, 4]); // (r, d/h)
    let svd = crate::linalg::svd(&g5);
    assert_eq!(svd.s.iter().filter(|&&s| s > 1e-5).count(), 3);
}

#[test]
fn zero_at_init_still_holds_after_fix() {
    let mut rng = Pcg64::new(4);
    for kind in [MetaTtKind::FourD, MetaTtKind::FiveD, MetaTtKind::FourPlusOneD] {
        let tt = MetaTt::new_default(kind, dims(), 4, 2.0, &mut rng);
        let x = Tensor::randn(&[5, 16], 1.0, &mut rng);
        assert_eq!(tt.apply(&x, 0, 0, 0).max_abs(), 0.0);
    }
}

#[test]
fn left_boundary_identity_matrix_view() {
    // id-ze-id-id (Fig 3 ablation code): G1 = eye(D, r) in matrix view.
    let mut rng = Pcg64::new(5);
    let strat = InitStrategy::from_code("id-ze-id-id").unwrap();
    let tt = MetaTt::new(MetaTtKind::FourD, dims(), 4, 1.0, &strat, &mut rng);
    let g1 = tt.chain.core(0).reshape(&[16, 4]);
    for j in 0..16 {
        for b in 0..4 {
            assert_eq!(g1.at(j, b), if j == b { 1.0 } else { 0.0 });
        }
    }
}
