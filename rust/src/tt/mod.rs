//! Tensor-train (TT) container and the MetaTT adapter algebra.
//!
//! A TT decomposes an order-d tensor `G[i1..id]` into a chain of order-3
//! cores `G_k[r_{k-1}, n_k, r_k]` with boundary ranks `r_0 = r_d = 1`
//! (paper Eq. 1). MetaTT instantiates this chain over the *structural* axes
//! of a transformer:
//!
//! * **MetaTT-4D** — axes `(D_in, L, M, D_out)` (paper Eq. 2/5)
//! * **MetaTT-5D** — axes `(D_in, L, M, H, D_out/H)` (paper Eq. 3)
//! * **MetaTT-(4+1)D** — axes `(D_in, L, T, M, D_out)` (paper Eq. 6, MTL)
//!
//! This module owns the host-side TT: construction/init strategies
//! (Appendix A.1), slicing `ΔW_{l,m}` out of the chain, applying the adapter
//! to activations (the rust-side oracle for the Pallas kernel), full
//! materialization for tests, canonical orthogonalization, and the
//! **DMRG-inspired sweep of Algorithm 1** in [`dmrg`].

mod chain;
mod dmrg;
mod init;
#[cfg(test)]
mod init_boundary_test;
mod meta;

pub use chain::TtChain;
pub use dmrg::{dmrg_sweep, RankSchedule, SweepReport};
pub use init::{CoreInit, InitStrategy};
pub use meta::{MetaTt, MetaTtDims, MetaTtKind};
