//! TT initialization strategies (paper §3 "Initialization of MetaTT PEFT"
//! and Appendix A.1 / Figure 3).
//!
//! The LoRA condition requires the adapter to be an exact zero map at step 0.
//! Any single zero core achieves that; the paper's default is `ze-id-id-id`:
//! first core zero, every other core's matrix slices the identity. Appendix
//! A.1 also evaluates normal-initialized cores ('no', N(0, 0.2)) in various
//! positions, which `fig3_init_strategies` reproduces.

use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// How to initialize one TT core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoreInit {
    /// All entries zero ('ze').
    Zero,
    /// Each matrix slice `G_k[j]` is the (rectangular) identity ('id').
    Identity,
    /// Entries drawn from N(0, 0.2) ('no', Appendix A.1).
    Normal,
}

impl CoreInit {
    /// Parse the two-letter code used in the paper's Figure 3 legend.
    pub fn from_code(code: &str) -> Result<CoreInit, String> {
        match code {
            "ze" => Ok(CoreInit::Zero),
            "id" => Ok(CoreInit::Identity),
            "no" => Ok(CoreInit::Normal),
            other => Err(format!("unknown init code '{other}' (want ze|id|no)")),
        }
    }

    pub fn code(&self) -> &'static str {
        match self {
            CoreInit::Zero => "ze",
            CoreInit::Identity => "id",
            CoreInit::Normal => "no",
        }
    }

    /// Build an *interior* core of shape `[r_left, n, r_right]`: 'id' sets
    /// every matrix slice `G_k[j]` to the (rectangular) identity.
    pub fn build(&self, r_left: usize, n: usize, r_right: usize, rng: &mut Pcg64) -> Tensor {
        match self {
            CoreInit::Zero => Tensor::zeros(&[r_left, n, r_right]),
            CoreInit::Identity => {
                let mut t = Tensor::zeros(&[r_left, n, r_right]);
                let eye = Tensor::eye_rect(r_left, r_right);
                for j in 0..n {
                    t.set_mid_slice(j, &eye);
                }
                t
            }
            CoreInit::Normal => Tensor::randn(&[r_left, n, r_right], 0.2, rng),
        }
    }

    /// Build a *boundary* core. The paper's Algorithm 3 applies
    /// `nn.init.eye_` to the boundary cores' natural **matrix view** —
    /// `G1 ∈ R^{n×r}` (left, stored `[1, n, r]`) or `Gd ∈ R^{r×n}` (right,
    /// stored `[r, n, 1]`) — NOT to each slice. Slice-level identity on a
    /// boundary core (`e_0` per slice) would route every bond through
    /// channel 0 and collapse the whole adapter to rank 1 regardless of r.
    pub fn build_boundary(
        &self,
        r_left: usize,
        n: usize,
        r_right: usize,
        rng: &mut Pcg64,
    ) -> Tensor {
        debug_assert!(r_left == 1 || r_right == 1, "not a boundary core");
        match self {
            CoreInit::Identity => {
                let mut t = Tensor::zeros(&[r_left, n, r_right]);
                if r_left == 1 {
                    // left boundary: matrix view (n, r_right), eye -> t[0,j,b] = δ_{jb}
                    for j in 0..n.min(r_right) {
                        t.set3(0, j, j, 1.0);
                    }
                } else {
                    // right boundary: matrix view (r_left, n), eye -> t[a,j,0] = δ_{aj}
                    for a in 0..r_left.min(n) {
                        t.set3(a, a, 0, 1.0);
                    }
                }
                t
            }
            other => other.build(r_left, n, r_right, rng),
        }
    }
}

/// A per-core initialization recipe, e.g. `ze-id-id-id` (the paper default).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InitStrategy {
    pub cores: Vec<CoreInit>,
}

impl InitStrategy {
    /// The paper's default for a d-core chain: first core zero, rest identity.
    pub fn paper_default(order: usize) -> InitStrategy {
        let mut cores = vec![CoreInit::Identity; order];
        cores[0] = CoreInit::Zero;
        InitStrategy { cores }
    }

    /// Parse a dash-separated code string like "ze-id-no-id".
    pub fn from_code(code: &str) -> Result<InitStrategy, String> {
        let cores = code
            .split('-')
            .map(CoreInit::from_code)
            .collect::<Result<Vec<_>, _>>()?;
        if cores.is_empty() {
            return Err("empty init code".into());
        }
        Ok(InitStrategy { cores })
    }

    pub fn code(&self) -> String {
        self.cores.iter().map(|c| c.code()).collect::<Vec<_>>().join("-")
    }

    /// Does this strategy guarantee a zero adapter at step 0? True iff at
    /// least one core is all-zero (paper Appendix A.1: the TT contraction is
    /// zero along every slice iff some core vanishes).
    pub fn is_zero_at_init(&self) -> bool {
        self.cores.iter().any(|c| *c == CoreInit::Zero)
    }

    /// All 3^d init-code combinations for an order-d chain that satisfy the
    /// zero-at-init condition — the Figure 3 ablation grid generator.
    pub fn zero_preserving_grid(order: usize) -> Vec<InitStrategy> {
        let opts = [CoreInit::Zero, CoreInit::Identity, CoreInit::Normal];
        let mut out = Vec::new();
        let total = 3usize.pow(order as u32);
        for mask in 0..total {
            let mut m = mask;
            let cores: Vec<CoreInit> = (0..order)
                .map(|_| {
                    let c = opts[m % 3];
                    m /= 3;
                    c
                })
                .collect();
            let s = InitStrategy { cores };
            if s.is_zero_at_init() {
                out.push(s);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_roundtrip() {
        let s = InitStrategy::from_code("ze-id-no-id").unwrap();
        assert_eq!(s.code(), "ze-id-no-id");
        assert!(s.is_zero_at_init());
        assert!(InitStrategy::from_code("xx-id").is_err());
    }

    #[test]
    fn paper_default_is_ze_then_id() {
        let s = InitStrategy::paper_default(4);
        assert_eq!(s.code(), "ze-id-id-id");
        assert!(s.is_zero_at_init());
    }

    #[test]
    fn identity_core_slices_are_identity() {
        let mut rng = Pcg64::new(1);
        let c = CoreInit::Identity.build(3, 5, 3, &mut rng);
        for j in 0..5 {
            assert_eq!(c.mid_slice(j), Tensor::eye(3));
        }
        // rectangular case
        let c2 = CoreInit::Identity.build(2, 4, 3, &mut rng);
        assert_eq!(c2.mid_slice(1), Tensor::eye_rect(2, 3));
    }

    #[test]
    fn grid_only_contains_zero_preserving() {
        let grid = InitStrategy::zero_preserving_grid(3);
        // 3^3 = 27 total; strategies with no 'ze' are 2^3 = 8; expect 19.
        assert_eq!(grid.len(), 19);
        assert!(grid.iter().all(|s| s.is_zero_at_init()));
    }
}
