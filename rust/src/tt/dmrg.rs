//! DMRG-inspired rank-adaptive sweep — paper Algorithm 1 (§3.3).
//!
//! Starting from a (sufficiently high-rank) TT, one sweep does:
//!
//! 1. left→right: for i = 1..d-1, merge cores (i, i+1), truncated-SVD to the
//!    target rank, store `U` on the left and `S·Vᵀ` on the right — leaving
//!    the left part of the chain in left-canonical (isometric) form;
//! 2. right→left: for i = d..2, merge (i-1, i), truncated-SVD, store `U·S`
//!    left and `Vᵀ` right.
//!
//! After the double sweep every interior bond is at most the target rank and
//! the dropped weight at each bond is exactly the tail of the local singular
//! spectrum. The sweep changes parameter *shapes*, so the caller (the
//! coordinator's DMRG scheduler) must reinitialize Adam moments and swap in
//! the matching-rank HLO executable afterwards — both handled in
//! `coordinator::dmrg`.

use super::chain::TtChain;
use crate::linalg::truncated_svd_with_tail;

/// Per-bond report of one sweep.
#[derive(Clone, Debug, Default)]
pub struct SweepReport {
    /// Interior bond ranks after the sweep.
    pub ranks: Vec<usize>,
    /// Relative truncation weight dropped per bond,
    /// `sqrt(Σ_{k>r} σ_k²) / sqrt(Σ_k σ_k²)`, maximized over the two passes
    /// (the left→right pass does the first, usually dominant, truncation).
    pub dropped: Vec<f32>,
}

impl SweepReport {
    /// Largest per-bond relative truncation loss.
    pub fn max_dropped(&self) -> f32 {
        self.dropped.iter().fold(0.0f32, |m, &x| m.max(x))
    }
}

/// Run one full DMRG-inspired double sweep, truncating every interior bond
/// to at most `target(bond_index)`. Returns the per-bond report.
pub fn dmrg_sweep(tt: &mut TtChain, target: &dyn Fn(usize) -> usize) -> SweepReport {
    let d = tt.order();
    assert!(d >= 2, "sweep needs at least two cores");
    let mut report = SweepReport::default();

    // Left→right pass (Algorithm 1, lines 1-5).
    report.dropped = vec![0.0; d - 1];
    for i in 0..d - 1 {
        let merged = tt.merge_pair(i);
        let (svd, dropped) = truncated_svd_with_tail(&merged, target(i));
        report.dropped[i] = dropped;
        let (u, svt) = svd.split_left_canonical();
        let k = svd.s.len();
        let (rl, n1) = (tt.core(i).shape()[0], tt.core(i).shape()[1]);
        let (n2, rr) = (tt.core(i + 1).shape()[1], tt.core(i + 1).shape()[2]);
        tt.replace_pair(
            i,
            u.reshape(&[rl, n1, k]),
            svt.reshape(&[k, n2, rr]),
        );
    }

    // Right→left pass (Algorithm 1, lines 6-10), collecting dropped weight.
    for i in (1..d).rev() {
        let merged = tt.merge_pair(i - 1);
        let (svd, dropped) = truncated_svd_with_tail(&merged, target(i - 1));
        report.dropped[i - 1] = report.dropped[i - 1].max(dropped);
        let (us, vt) = svd.split_right_canonical();
        let k = svd.s.len();
        let (rl, n1) = (tt.core(i - 1).shape()[0], tt.core(i - 1).shape()[1]);
        let (n2, rr) = (tt.core(i).shape()[1], tt.core(i).shape()[2]);
        tt.replace_pair(
            i - 1,
            us.reshape(&[rl, n1, k]),
            vt.reshape(&[k, n2, rr]),
        );
    }

    report.ranks = tt.ranks();
    report
}

/// A rank-annealing schedule for DMRG training (paper Figs 2/6: start at
/// r=10, progressively lower to r=4 at chosen epochs).
#[derive(Clone, Debug)]
pub struct RankSchedule {
    /// (epoch, target_rank), ascending by epoch. A sweep to `rank` fires
    /// *after* training epoch `epoch`.
    pub steps: Vec<(usize, usize)>,
}

impl RankSchedule {
    /// The paper's Figure 2 schedule shape: anneal from `start` down to
    /// `end`, one unit of rank every `every` epochs beginning at
    /// `first_epoch`.
    pub fn anneal(start: usize, end: usize, first_epoch: usize, every: usize) -> RankSchedule {
        assert!(start >= end && end >= 1 && every >= 1);
        let steps = (0..=(start - end))
            .map(|k| (first_epoch + k * every, start - k))
            .collect();
        RankSchedule { steps }
    }

    /// Parse "epoch:rank,epoch:rank,…" from the CLI.
    pub fn parse(s: &str) -> Result<RankSchedule, String> {
        let mut steps = Vec::new();
        for part in s.split(',') {
            let (e, r) = part
                .split_once(':')
                .ok_or_else(|| format!("bad schedule entry '{part}' (want epoch:rank)"))?;
            let e: usize = e.trim().parse().map_err(|_| format!("bad epoch '{e}'"))?;
            let r: usize = r.trim().parse().map_err(|_| format!("bad rank '{r}'"))?;
            steps.push((e, r));
        }
        if steps.is_empty() {
            return Err("empty schedule".into());
        }
        for w in steps.windows(2) {
            if w[1].0 <= w[0].0 {
                return Err("schedule epochs must be strictly increasing".into());
            }
        }
        Ok(RankSchedule { steps })
    }

    /// Target rank to sweep to right after `epoch`, if any.
    pub fn rank_after_epoch(&self, epoch: usize) -> Option<usize> {
        self.steps.iter().find(|(e, _)| *e == epoch).map(|(_, r)| *r)
    }

    /// The smallest rank in the schedule (final target).
    pub fn final_rank(&self) -> usize {
        self.steps.iter().map(|(_, r)| *r).min().unwrap()
    }

    /// All distinct ranks the schedule visits, including `start_rank`,
    /// descending — the set of HLO artifacts the run needs.
    pub fn ranks_visited(&self, start_rank: usize) -> Vec<usize> {
        let mut ranks: Vec<usize> = std::iter::once(start_rank)
            .chain(self.steps.iter().map(|(_, r)| *r))
            .collect();
        ranks.sort_unstable_by(|a, b| b.cmp(a));
        ranks.dedup();
        ranks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{rel_err, Tensor};
    use crate::testutil::prop_check;
    use crate::tt::chain::random_chain;
    use crate::util::rng::Pcg64;

    #[test]
    fn sweep_at_same_rank_is_exact() {
        prop_check("same-rank sweep exact", 8, |rng, _| {
            let tt0 = random_chain(rng, &[4, 3, 5, 3], 3);
            let full0 = tt0.materialize();
            let mut tt = tt0.clone();
            let rep = dmrg_sweep(&mut tt, &|_| 16); // rank cap above actual
            let full1 = tt.materialize();
            let err = rel_err(&full1, &full0);
            if err < 1e-4 && rep.max_dropped() < 1e-5 {
                Ok(())
            } else {
                Err(format!("err {err} dropped {}", rep.max_dropped()))
            }
        });
    }

    #[test]
    fn sweep_truncates_to_target_ranks() {
        let mut rng = Pcg64::new(1);
        let mut tt = random_chain(&mut rng, &[6, 4, 4, 6], 5);
        let rep = dmrg_sweep(&mut tt, &|_| 2);
        assert!(rep.ranks.iter().all(|&r| r <= 2), "{:?}", rep.ranks);
        assert_eq!(tt.ranks(), rep.ranks);
        // Shapes remain a valid chain and modes unchanged.
        assert_eq!(tt.mode_sizes(), vec![6, 4, 4, 6]);
    }

    #[test]
    fn truncation_error_bounded_by_reported_drops() {
        let mut rng = Pcg64::new(2);
        let tt0 = random_chain(&mut rng, &[5, 4, 3, 5], 4);
        let full0 = tt0.materialize();
        let mut tt = tt0.clone();
        let rep = dmrg_sweep(&mut tt, &|_| 2);
        let full1 = tt.materialize();
        let err = full1.sub(&full0).fro_norm() / full0.fro_norm();
        // TT-SVD bound: error ≤ sqrt(Σ_bonds dropped²) (relative, loose here
        // because the right-left pass drops on already-truncated data).
        let bound: f32 =
            rep.dropped.iter().map(|&d| d * d).sum::<f32>().sqrt() * 2.0 + 1e-4;
        assert!(err <= bound, "err {err} bound {bound}");
        assert!(err > 1e-6, "rank-2 truncation of rank-4 data must be lossy");
    }

    #[test]
    fn sweep_recovers_exactly_lowrank_data() {
        // Build a chain that is *actually* rank 2 but stored with rank 5
        // padding; a sweep to rank 2 must be loss-free.
        let mut rng = Pcg64::new(3);
        let tt2 = random_chain(&mut rng, &[5, 3, 4], 2);
        let full = tt2.materialize();
        // Re-express at rank 5 by zero-padding cores.
        let mut padded_cores = Vec::new();
        for (k, c) in tt2.cores().iter().enumerate() {
            let (rl, n, rr) = (c.shape()[0], c.shape()[1], c.shape()[2]);
            let (prl, prr) = (
                if k == 0 { 1 } else { 5 },
                if k == tt2.order() - 1 { 1 } else { 5 },
            );
            let mut p = Tensor::zeros(&[prl, n, prr]);
            for a in 0..rl {
                for j in 0..n {
                    for b in 0..rr {
                        p.set3(a, j, b, c.at3(a, j, b));
                    }
                }
            }
            padded_cores.push(p);
        }
        let mut padded = TtChain::new(padded_cores);
        assert_eq!(padded.max_rank(), 5);
        let rep = dmrg_sweep(&mut padded, &|_| 2);
        assert!(rep.ranks.iter().all(|&r| r <= 2));
        assert!(rel_err(&padded.materialize(), &full) < 1e-4);
        assert!(rep.max_dropped() < 1e-4);
    }

    #[test]
    fn repeated_sweeps_are_stable() {
        let mut rng = Pcg64::new(4);
        let mut tt = random_chain(&mut rng, &[5, 4, 5], 4);
        dmrg_sweep(&mut tt, &|_| 3);
        let once = tt.materialize();
        let rep = dmrg_sweep(&mut tt, &|_| 3);
        let twice = tt.materialize();
        // A second sweep at the same rank must be a (near) no-op.
        assert!(rel_err(&twice, &once) < 1e-4);
        assert!(rep.max_dropped() < 1e-5);
    }

    #[test]
    fn schedule_anneal_and_parse() {
        let s = RankSchedule::anneal(10, 4, 2, 3);
        assert_eq!(s.steps.first(), Some(&(2, 10)));
        assert_eq!(s.final_rank(), 4);
        assert_eq!(s.ranks_visited(10), vec![10, 9, 8, 7, 6, 5, 4]);
        let p = RankSchedule::parse("3:8,6:6,9:4").unwrap();
        assert_eq!(p.rank_after_epoch(6), Some(6));
        assert_eq!(p.rank_after_epoch(7), None);
        assert!(RankSchedule::parse("5:4,5:3").is_err());
        assert!(RankSchedule::parse("x").is_err());
    }
}
