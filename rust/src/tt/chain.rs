//! The generic TT chain: cores, contraction, slicing, orthogonalization.

use crate::linalg;
use crate::tensor::Tensor;

/// A tensor train: `cores[k]` has shape `[r_{k-1}, n_k, r_k]` with boundary
/// ranks `r_0 = r_d = 1`.
#[derive(Clone, Debug)]
pub struct TtChain {
    cores: Vec<Tensor>,
}

impl TtChain {
    /// Build from cores; validates the rank chain.
    pub fn new(cores: Vec<Tensor>) -> TtChain {
        assert!(!cores.is_empty(), "TT needs at least one core");
        for c in &cores {
            assert_eq!(c.ndim(), 3, "TT cores are order-3, got {:?}", c.shape());
        }
        assert_eq!(cores[0].shape()[0], 1, "left boundary rank must be 1");
        assert_eq!(cores.last().unwrap().shape()[2], 1, "right boundary rank must be 1");
        for w in cores.windows(2) {
            assert_eq!(
                w[0].shape()[2],
                w[1].shape()[0],
                "bond mismatch: {:?} -> {:?}",
                w[0].shape(),
                w[1].shape()
            );
        }
        TtChain { cores }
    }

    /// Number of cores (the order d of the represented tensor).
    pub fn order(&self) -> usize {
        self.cores.len()
    }

    /// Mode sizes `n_1..n_d`.
    pub fn mode_sizes(&self) -> Vec<usize> {
        self.cores.iter().map(|c| c.shape()[1]).collect()
    }

    /// Interior bond ranks `r_1..r_{d-1}` (boundary 1s omitted).
    pub fn ranks(&self) -> Vec<usize> {
        self.cores[..self.cores.len() - 1]
            .iter()
            .map(|c| c.shape()[2])
            .collect()
    }

    /// Largest interior bond rank.
    pub fn max_rank(&self) -> usize {
        self.ranks().into_iter().max().unwrap_or(1)
    }

    pub fn core(&self, k: usize) -> &Tensor {
        &self.cores[k]
    }

    pub fn core_mut(&mut self, k: usize) -> &mut Tensor {
        &mut self.cores[k]
    }

    pub fn cores(&self) -> &[Tensor] {
        &self.cores
    }

    /// Replace cores i and i+1 (used by the DMRG sweep).
    pub(crate) fn replace_pair(&mut self, i: usize, left: Tensor, right: Tensor) {
        assert_eq!(left.shape()[1], self.cores[i].shape()[1]);
        assert_eq!(right.shape()[1], self.cores[i + 1].shape()[1]);
        assert_eq!(left.shape()[2], right.shape()[0]);
        assert_eq!(left.shape()[0], self.cores[i].shape()[0]);
        assert_eq!(right.shape()[2], self.cores[i + 1].shape()[2]);
        self.cores[i] = left;
        self.cores[i + 1] = right;
    }

    /// Total number of stored parameters.
    pub fn param_count(&self) -> usize {
        self.cores.iter().map(|c| c.len()).sum()
    }

    /// The matrix slice `G_k[j]` (r_{k-1} × r_k) of core k.
    pub fn slice(&self, k: usize, j: usize) -> Tensor {
        self.cores[k].mid_slice(j)
    }

    /// Evaluate one scalar entry `G[i1..id]` (tests / tiny tensors only).
    pub fn entry(&self, idx: &[usize]) -> f32 {
        assert_eq!(idx.len(), self.order());
        let mut acc = self.slice(0, idx[0]);
        for (k, &j) in idx.iter().enumerate().skip(1) {
            acc = acc.matmul(&self.slice(k, j));
        }
        debug_assert_eq!(acc.shape(), &[1, 1]);
        acc.data()[0]
    }

    /// Materialize the full tensor, row-major over the mode indices.
    /// Exponential in d — test use only.
    pub fn materialize(&self) -> Tensor {
        let modes = self.mode_sizes();
        let total: usize = modes.iter().product();
        assert!(total <= 1 << 22, "materialize() is for small tensors");
        // Left-to-right accumulation: rows = multi-index prefix, cols = bond.
        // acc starts as core0 flattened: (n_1) x r_1.
        let c0 = &self.cores[0];
        let mut acc = c0.reshape(&[modes[0], c0.shape()[2]]);
        for k in 1..self.order() {
            let ck = &self.cores[k];
            let (rl, n, rr) = (ck.shape()[0], ck.shape()[1], ck.shape()[2]);
            // acc: (P x rl) · core (rl x (n·rr)) = P x (n·rr) -> (P·n) x rr
            let ck_mat = ck.reshape(&[rl, n * rr]);
            acc = acc.matmul(&ck_mat).reshape_inplace(&[acc.shape()[0] * n, rr]);
        }
        acc.reshape_inplace(&modes)
    }

    /// Contract a sub-chain of *middle* cores at fixed indices into a single
    /// r×r matrix: `G_a[i_a]·…·G_b[i_b]` for cores `a..=b`.
    pub fn middle_product(&self, a: usize, b: usize, idx: &[usize]) -> Tensor {
        assert_eq!(idx.len(), b - a + 1);
        let mut acc = self.slice(a, idx[0]);
        for (off, &j) in idx.iter().enumerate().skip(1) {
            acc = acc.matmul(&self.slice(a + off, j));
        }
        acc
    }

    /// Frobenius norm of the represented tensor, computed stably via
    /// right-to-left contraction of the Gram chain (no materialization).
    pub fn fro_norm(&self) -> f32 {
        // E_k = sum_j core_k[.., j, ..] E_{k+1} core_k[.., j, ..]^T, E_d = [[1]]
        let mut e = Tensor::eye(1);
        for k in (0..self.order()).rev() {
            let c = &self.cores[k];
            let (rl, n, _rr) = (c.shape()[0], c.shape()[1], c.shape()[2]);
            let mut next = Tensor::zeros(&[rl, rl]);
            for j in 0..n {
                let s = c.mid_slice(j);
                let m = s.matmul(&e).matmul_t(&s);
                next.axpy(1.0, &m);
            }
            e = next;
        }
        e.data()[0].max(0.0).sqrt()
    }

    /// Left-orthogonalize cores `0..pivot` in place (QR push). After this,
    /// each core k < pivot satisfies `sum_j G_k[j]^T G_k[j] = I`.
    pub fn left_orthogonalize(&mut self, pivot: usize) {
        assert!(pivot < self.order());
        for k in 0..pivot {
            let c = &self.cores[k];
            let (rl, n, rr) = (c.shape()[0], c.shape()[1], c.shape()[2]);
            let mat = c.reshape(&[rl * n, rr]);
            let (q, r) = linalg::qr(&mat);
            let new_rr = q.cols();
            self.cores[k] = q.reshape(&[rl, n, new_rr]);
            // Push R into the next core: new_{k+1}[a, j, c] = sum_b R[a,b] G[b,j,c]
            let nx = &self.cores[k + 1];
            let (nrl, nn, nrr) = (nx.shape()[0], nx.shape()[1], nx.shape()[2]);
            let nx_mat = nx.reshape(&[nrl, nn * nrr]);
            self.cores[k + 1] = r.matmul(&nx_mat).reshape_inplace(&[new_rr, nn, nrr]);
        }
    }

    /// Right-orthogonalize cores `pivot+1..d` in place (LQ push, mirrored).
    pub fn right_orthogonalize(&mut self, pivot: usize) {
        assert!(pivot < self.order());
        for k in (pivot + 1..self.order()).rev() {
            let c = &self.cores[k];
            let (rl, n, rr) = (c.shape()[0], c.shape()[1], c.shape()[2]);
            // LQ of (rl x n·rr) == transpose of QR of (n·rr x rl).
            let mat_t = c.reshape(&[rl, n * rr]).transpose();
            let (q, r) = linalg::qr(&mat_t);
            let new_rl = q.cols();
            self.cores[k] = q.transpose().reshape_inplace(&[new_rl, n, rr]);
            // Push R^T into the previous core (multiply on its right bond).
            let pv = &self.cores[k - 1];
            let (prl, pn, prr) = (pv.shape()[0], pv.shape()[1], pv.shape()[2]);
            debug_assert_eq!(prr, rl);
            let pv_mat = pv.reshape(&[prl * pn, prr]);
            self.cores[k - 1] = pv_mat.matmul_t(&r).reshape_inplace(&[prl, pn, new_rl]);
        }
    }

    /// Merge cores i and i+1 into the matrix `(r_{i-1}·n_i) × (n_{i+1}·r_{i+1})`
    /// — the MERGE step of Algorithm 1.
    pub fn merge_pair(&self, i: usize) -> Tensor {
        let (a, b) = (&self.cores[i], &self.cores[i + 1]);
        let (rl, n1, rm) = (a.shape()[0], a.shape()[1], a.shape()[2]);
        let (_, n2, rr) = (b.shape()[0], b.shape()[1], b.shape()[2]);
        let am = a.reshape(&[rl * n1, rm]);
        let bm = b.reshape(&[rm, n2 * rr]);
        am.matmul(&bm) // (rl·n1) x (n2·rr)
    }

    /// Flatten all cores into one parameter vector (canonical order: cores
    /// left→right, each row-major). Matches the python export layout.
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.param_count());
        for c in &self.cores {
            out.extend_from_slice(c.data());
        }
        out
    }

    /// Inverse of [`flatten`] given the current core shapes.
    pub fn unflatten(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.param_count(), "flat param size mismatch");
        let mut off = 0;
        for c in &mut self.cores {
            let n = c.len();
            c.data_mut().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rel_err;
    use crate::testutil::prop_check;
    use crate::util::rng::Pcg64;

    pub(crate) fn random_chain(
        rng: &mut Pcg64,
        modes: &[usize],
        rank: usize,
    ) -> TtChain {
        let d = modes.len();
        let mut cores = Vec::new();
        for k in 0..d {
            let rl = if k == 0 { 1 } else { rank };
            let rr = if k == d - 1 { 1 } else { rank };
            cores.push(Tensor::randn(&[rl, modes[k], rr], 0.5, rng));
        }
        TtChain::new(cores)
    }

    #[test]
    fn entry_matches_materialize() {
        let mut rng = Pcg64::new(1);
        let tt = random_chain(&mut rng, &[3, 4, 2, 3], 3);
        let full = tt.materialize();
        // full is row-major over modes [3,4,2,3]
        let strides = [4 * 2 * 3, 2 * 3, 3, 1];
        for idx in [[0, 0, 0, 0], [2, 3, 1, 2], [1, 2, 0, 1]] {
            let flat: usize = idx.iter().zip(strides).map(|(&i, s)| i * s).sum();
            let want = full.data()[flat];
            let got = tt.entry(&idx);
            assert!((got - want).abs() < 1e-4, "idx {:?}: {got} vs {want}", idx);
        }
    }

    #[test]
    fn fro_norm_matches_materialized() {
        let mut rng = Pcg64::new(2);
        let tt = random_chain(&mut rng, &[4, 3, 5], 4);
        let want = tt.materialize().fro_norm();
        let got = tt.fro_norm();
        assert!((got - want).abs() / want < 1e-4, "{got} vs {want}");
    }

    #[test]
    fn orthogonalization_preserves_tensor() {
        prop_check("orthogonalize preserves", 10, |rng, case| {
            let modes = vec![3, 4, 3, 2];
            let tt0 = random_chain(rng, &modes, 3);
            let full0 = tt0.materialize();
            let mut tt = tt0.clone();
            if case % 2 == 0 {
                tt.left_orthogonalize(modes.len() - 1);
            } else {
                tt.right_orthogonalize(0);
            }
            let full1 = tt.materialize();
            let err = rel_err(&full1, &full0);
            if err < 1e-4 {
                Ok(())
            } else {
                Err(format!("err {err}"))
            }
        });
    }

    #[test]
    fn left_orthogonal_cores_are_isometries() {
        let mut rng = Pcg64::new(3);
        let mut tt = random_chain(&mut rng, &[3, 4, 3, 2], 3);
        tt.left_orthogonalize(3);
        for k in 0..3 {
            let c = tt.core(k);
            let (rl, n, rr) = (c.shape()[0], c.shape()[1], c.shape()[2]);
            let m = c.reshape(&[rl * n, rr]);
            let gram = m.t_matmul(&m);
            assert!(rel_err(&gram, &Tensor::eye(rr)) < 1e-4, "core {k}");
        }
    }

    #[test]
    fn merge_pair_contracts_correctly() {
        let mut rng = Pcg64::new(4);
        let tt = random_chain(&mut rng, &[2, 3, 4], 3);
        let merged = tt.merge_pair(0); // (1*2) x (3*3)
        assert_eq!(merged.shape(), &[2, 9]);
        // Check one entry against slice products.
        // merged[(0*2+i1), (j*3+b)] = sum_a G0[0,i1,a] G1[a,j,b]
        let want = tt.slice(0, 1).matmul(&tt.slice(1, 2));
        for b in 0..3 {
            let got = merged.at(1, 2 * 3 + b);
            assert!((got - want.at(0, b)).abs() < 1e-5);
        }
    }

    #[test]
    fn flatten_roundtrip() {
        let mut rng = Pcg64::new(5);
        let tt = random_chain(&mut rng, &[3, 2, 4], 2);
        let flat = tt.flatten();
        assert_eq!(flat.len(), tt.param_count());
        let mut tt2 = random_chain(&mut rng, &[3, 2, 4], 2);
        tt2.unflatten(&flat);
        for k in 0..tt.order() {
            assert_eq!(tt.core(k), tt2.core(k));
        }
    }

    #[test]
    #[should_panic(expected = "bond mismatch")]
    fn bad_bond_rejected() {
        let a = Tensor::zeros(&[1, 3, 2]);
        let b = Tensor::zeros(&[3, 3, 1]);
        TtChain::new(vec![a, b]);
    }
}

#[cfg(test)]
pub(crate) use tests::random_chain;
