//! MetaTT adapters: the TT chain bound to transformer structural axes.

use super::chain::TtChain;
use super::init::InitStrategy;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Which MetaTT variant (paper §2.2–2.3, §3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetaTtKind {
    /// Axes (D_in, L, M, D_out).
    FourD,
    /// Axes (D_in, L, M, H, D_out/H).
    FiveD,
    /// Axes (D_in, L, T, M, D_out) — the MTL variant with a task core in the
    /// middle of the chain ("for symmetry", paper §3.2).
    FourPlusOneD,
}

impl MetaTtKind {
    pub fn name(&self) -> &'static str {
        match self {
            MetaTtKind::FourD => "metatt4d",
            MetaTtKind::FiveD => "metatt5d",
            MetaTtKind::FourPlusOneD => "metatt4p1d",
        }
    }

    pub fn from_name(s: &str) -> Result<MetaTtKind, String> {
        match s {
            "metatt4d" => Ok(MetaTtKind::FourD),
            "metatt5d" => Ok(MetaTtKind::FiveD),
            "metatt4p1d" => Ok(MetaTtKind::FourPlusOneD),
            other => Err(format!("unknown MetaTT kind '{other}'")),
        }
    }

    /// Chain order d.
    pub fn order(&self) -> usize {
        match self {
            MetaTtKind::FourD => 4,
            MetaTtKind::FiveD => 5,
            MetaTtKind::FourPlusOneD => 5,
        }
    }
}

/// Structural dimensions of the adapted transformer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MetaTtDims {
    /// Input feature dim (D_in).
    pub d_in: usize,
    /// Output feature dim (D_out).
    pub d_out: usize,
    /// Number of transformer layers (L).
    pub layers: usize,
    /// Number of adapted projection matrices per layer (M; Q,V → 2).
    pub matrices: usize,
    /// Attention heads (H; 5D variant only).
    pub heads: usize,
    /// Number of tasks (T; (4+1)D variant only).
    pub tasks: usize,
}

/// The global MetaTT adapter: one TT chain shared by every adapted linear
/// map in the network, with slicing by (layer, matrix[, head, task]).
#[derive(Clone, Debug)]
pub struct MetaTt {
    pub kind: MetaTtKind,
    pub dims: MetaTtDims,
    /// Scaling factor α applied to the adapter output (paper Eq. 5).
    pub alpha: f32,
    pub chain: TtChain,
}

impl MetaTt {
    /// Derive TT dims from transformer model dims (square attention
    /// projections: D_in = D_out = hidden).
    pub fn dims_from_model(
        _kind: MetaTtKind,
        m: &crate::adapters::ModelDims,
    ) -> MetaTtDims {
        MetaTtDims {
            d_in: m.hidden,
            d_out: m.hidden,
            layers: m.layers,
            matrices: m.matrices,
            heads: m.heads,
            tasks: m.tasks,
        }
    }

    /// Mode sizes for a variant given dims.
    pub fn mode_sizes(kind: MetaTtKind, dims: &MetaTtDims) -> Vec<usize> {
        match kind {
            MetaTtKind::FourD => vec![dims.d_in, dims.layers, dims.matrices, dims.d_out],
            MetaTtKind::FiveD => {
                assert!(
                    dims.d_out % dims.heads == 0,
                    "D_out {} not divisible by H {}",
                    dims.d_out,
                    dims.heads
                );
                vec![
                    dims.d_in,
                    dims.layers,
                    dims.matrices,
                    dims.heads,
                    dims.d_out / dims.heads,
                ]
            }
            MetaTtKind::FourPlusOneD => vec![
                dims.d_in,
                dims.layers,
                dims.tasks,
                dims.matrices,
                dims.d_out,
            ],
        }
    }

    /// Create with uniform interior rank `r` and the given init strategy.
    pub fn new(
        kind: MetaTtKind,
        dims: MetaTtDims,
        rank: usize,
        alpha: f32,
        init: &InitStrategy,
        rng: &mut Pcg64,
    ) -> MetaTt {
        let modes = Self::mode_sizes(kind, &dims);
        assert_eq!(
            init.cores.len(),
            modes.len(),
            "init strategy order {} != chain order {}",
            init.cores.len(),
            modes.len()
        );
        let d = modes.len();
        let cores: Vec<Tensor> = (0..d)
            .map(|k| {
                let rl = if k == 0 { 1 } else { rank };
                let rr = if k == d - 1 { 1 } else { rank };
                if k == 0 || k == d - 1 {
                    init.cores[k].build_boundary(rl, modes[k], rr, rng)
                } else {
                    init.cores[k].build(rl, modes[k], rr, rng)
                }
            })
            .collect();
        MetaTt { kind, dims, alpha, chain: TtChain::new(cores) }
    }

    /// Create with the paper-default init (ze-id-id-…).
    pub fn new_default(
        kind: MetaTtKind,
        dims: MetaTtDims,
        rank: usize,
        alpha: f32,
        rng: &mut Pcg64,
    ) -> MetaTt {
        let init = InitStrategy::paper_default(kind.order());
        Self::new(kind, dims, rank, alpha, &init, rng)
    }

    /// Trainable parameter count (exact; the complexity bench checks this
    /// against the paper's closed forms).
    pub fn param_count(&self) -> usize {
        self.chain.param_count()
    }

    /// Materialize the adapter update `ΔW_{l,m}` (D_in × D_out), WITHOUT α.
    ///
    /// 4D: `G1 · G2[l] · G3[m] · G4` (paper Eq. 5).
    /// 5D: head-blocks concatenated along the output dim.
    /// (4+1)D: `G1 · G2[l] · G3[t] · G4[m] · G5` (paper Eq. 6).
    pub fn delta_w(&self, layer: usize, matrix: usize, task: usize) -> Tensor {
        let d_in = self.dims.d_in;
        match self.kind {
            MetaTtKind::FourD => {
                let g1 = self.chain.core(0).reshape(&[d_in, self.chain.core(0).shape()[2]]);
                let mid = self.chain.middle_product(1, 2, &[layer, matrix]);
                let g4 = self.last_core_matrix();
                g1.matmul(&mid).matmul(&g4)
            }
            MetaTtKind::FourPlusOneD => {
                let g1 = self.chain.core(0).reshape(&[d_in, self.chain.core(0).shape()[2]]);
                let mid = self.chain.middle_product(1, 3, &[layer, task, matrix]);
                let g5 = self.last_core_matrix();
                g1.matmul(&mid).matmul(&g5)
            }
            MetaTtKind::FiveD => {
                let g1 = self.chain.core(0).reshape(&[d_in, self.chain.core(0).shape()[2]]);
                let dh = self.dims.d_out / self.dims.heads;
                let mut out = Tensor::zeros(&[d_in, self.dims.d_out]);
                let lm = self.chain.middle_product(1, 2, &[layer, matrix]);
                let g5 = self.last_core_matrix(); // r4 x dh
                for h in 0..self.dims.heads {
                    let mid = lm.matmul(&self.chain.slice(3, h));
                    let blk = g1.matmul(&mid).matmul(&g5); // d_in x dh
                    for i in 0..d_in {
                        for j in 0..dh {
                            out.set(i, h * dh + j, blk.at(i, j));
                        }
                    }
                }
                out
            }
        }
    }

    /// Last core as a (r × n_d) matrix.
    fn last_core_matrix(&self) -> Tensor {
        let c = self.chain.core(self.chain.order() - 1);
        c.reshape(&[c.shape()[0], c.shape()[1]])
    }

    /// Apply the adapter to a batch: `α · X · ΔW_{l,m,t}` — the rust oracle
    /// for the Pallas kernel, contracted in the cheap order
    /// `(((X·G1)·mid)·G_last)` so no D×D intermediate is formed.
    pub fn apply(&self, x: &Tensor, layer: usize, matrix: usize, task: usize) -> Tensor {
        assert_eq!(x.cols(), self.dims.d_in);
        let g1 = self.chain.core(0).reshape(&[self.dims.d_in, self.chain.core(0).shape()[2]]);
        let xg = x.matmul(&g1); // N x r
        match self.kind {
            MetaTtKind::FourD => {
                let mid = self.chain.middle_product(1, 2, &[layer, matrix]);
                xg.matmul(&mid).matmul(&self.last_core_matrix()).scale(self.alpha)
            }
            MetaTtKind::FourPlusOneD => {
                let mid = self.chain.middle_product(1, 3, &[layer, task, matrix]);
                xg.matmul(&mid).matmul(&self.last_core_matrix()).scale(self.alpha)
            }
            MetaTtKind::FiveD => {
                let n = x.rows();
                let dh = self.dims.d_out / self.dims.heads;
                let lm = self.chain.middle_product(1, 2, &[layer, matrix]);
                let xlm = xg.matmul(&lm);
                let g5 = self.last_core_matrix();
                let mut out = Tensor::zeros(&[n, self.dims.d_out]);
                for h in 0..self.dims.heads {
                    let blk = xlm.matmul(&self.chain.slice(3, h)).matmul(&g5);
                    for i in 0..n {
                        for j in 0..dh {
                            out.set(i, h * dh + j, self.alpha * blk.at(i, j));
                        }
                    }
                }
                out
            }
        }
    }

    /// Number of tasks the adapter distinguishes: the task-core arity for
    /// the (4+1)D variant, 1 for the task-free 4D/5D variants (every task
    /// folds to the same factors). The serving engine's folded-adapter
    /// cache keys on this.
    pub fn distinct_tasks(&self) -> usize {
        match self.kind {
            MetaTtKind::FourPlusOneD => self.dims.tasks,
            _ => 1,
        }
    }

    /// Pre-merge the middle cores into the boundary for serving (paper §2.4:
    /// "merge the middle tensor cores with G1 or G4 once the adapters are
    /// trained"). Returns per-(l,m[,t]) factor pairs (A = G1·mid scaled by α,
    /// B = G_last) so serving does exactly two GEMMs like LoRA. The task
    /// index only selects a slice for the (4+1)D task core; 4D/5D ignore it.
    pub fn fold_for_serving(&self, task: usize) -> Vec<Vec<(Tensor, Tensor)>> {
        assert!(
            self.kind != MetaTtKind::FourPlusOneD || task < self.dims.tasks,
            "fold_for_serving: task {task} out of range ({} tasks)",
            self.dims.tasks
        );
        let g1 = self.chain.core(0).reshape(&[self.dims.d_in, self.chain.core(0).shape()[2]]);
        // Boundary factors are (l, m)-invariant — materialize them once
        // outside the loops instead of re-squeezing/re-scaling per pair
        // (the same prefix-reuse the reference backend's step applies).
        let g_last = self.last_core_matrix();
        let g1_scaled = g1.scale(self.alpha);
        let mut out = Vec::with_capacity(self.dims.layers);
        for l in 0..self.dims.layers {
            let mut row = Vec::with_capacity(self.dims.matrices);
            for m in 0..self.dims.matrices {
                let (a, b) = match self.kind {
                    MetaTtKind::FourD => {
                        let mid = self.chain.middle_product(1, 2, &[l, m]);
                        (g1.matmul(&mid).scale(self.alpha), g_last.clone())
                    }
                    MetaTtKind::FourPlusOneD => {
                        let mid = self.chain.middle_product(1, 3, &[l, task, m]);
                        (g1.matmul(&mid).scale(self.alpha), g_last.clone())
                    }
                    MetaTtKind::FiveD => {
                        // Fold heads into a block-diagonal-free form: build the
                        // full (r1 x D_out) right factor for this (l, m).
                        let lm = self.chain.middle_product(1, 2, &[l, m]);
                        let dh = self.dims.d_out / self.dims.heads;
                        let r1 = g1.cols();
                        let mut right = Tensor::zeros(&[r1, self.dims.d_out]);
                        for h in 0..self.dims.heads {
                            let rh = lm
                                .matmul(&self.chain.slice(3, h))
                                .matmul(&g_last); // r1 x dh
                            for i in 0..r1 {
                                for j in 0..dh {
                                    right.set(i, h * dh + j, rh.at(i, j));
                                }
                            }
                        }
                        (g1_scaled.clone(), right)
                    }
                };
                row.push((a, b));
            }
            out.push(row);
        }
        out
    }

    /// Export cores in the layout the python model consumes:
    /// boundary cores squeezed to matrices, interior cores permuted to
    /// `(n, r_left, r_right)` so `core[idx]` indexes the structural axis.
    pub fn export_cores(&self) -> Vec<Tensor> {
        let d = self.chain.order();
        (0..d)
            .map(|k| {
                let c = self.chain.core(k);
                let (rl, n, rr) = (c.shape()[0], c.shape()[1], c.shape()[2]);
                if k == 0 {
                    c.reshape(&[n, rr])
                } else if k == d - 1 {
                    c.reshape(&[rl, n])
                } else {
                    // [rl, n, rr] -> [n, rl, rr]
                    let mut out = Tensor::zeros(&[n, rl, rr]);
                    for a in 0..rl {
                        for j in 0..n {
                            for b in 0..rr {
                                out.set3(j, a, b, c.at3(a, j, b));
                            }
                        }
                    }
                    out
                }
            })
            .collect()
    }

    /// Inverse of [`export_cores`]: load updated cores (e.g. post-HLO-step
    /// values) back into the chain.
    pub fn import_cores(&mut self, exported: &[Tensor]) {
        let d = self.chain.order();
        assert_eq!(exported.len(), d);
        for k in 0..d {
            let cur = self.chain.core(k);
            let (rl, n, rr) = (cur.shape()[0], cur.shape()[1], cur.shape()[2]);
            let e = &exported[k];
            if k == 0 {
                assert_eq!(e.shape(), &[n, rr], "core 0 export shape");
                *self.chain.core_mut(k) = e.reshape(&[1, n, rr]);
            } else if k == d - 1 {
                assert_eq!(e.shape(), &[rl, n], "last core export shape");
                *self.chain.core_mut(k) = e.reshape(&[rl, n, 1]);
            } else {
                assert_eq!(e.shape(), &[n, rl, rr], "core {k} export shape");
                let mut out = Tensor::zeros(&[rl, n, rr]);
                for j in 0..n {
                    for a in 0..rl {
                        for b in 0..rr {
                            out.set3(a, j, b, e.at3(j, a, b));
                        }
                    }
                }
                *self.chain.core_mut(k) = out;
            }
        }
    }

    /// Shapes of the exported cores, in export order (for HLO input specs).
    pub fn export_shapes(&self) -> Vec<Vec<usize>> {
        self.export_cores().iter().map(|t| t.shape().to_vec()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rel_err;
    use crate::testutil::prop_check;

    fn dims4() -> MetaTtDims {
        MetaTtDims { d_in: 16, d_out: 16, layers: 3, matrices: 2, heads: 4, tasks: 3 }
    }

    #[test]
    fn default_init_is_zero_map() {
        let mut rng = Pcg64::new(1);
        for kind in [MetaTtKind::FourD, MetaTtKind::FiveD, MetaTtKind::FourPlusOneD] {
            let tt = MetaTt::new_default(kind, dims4(), 4, 2.0, &mut rng);
            let x = Tensor::randn(&[5, 16], 1.0, &mut rng);
            let y = tt.apply(&x, 1, 0, 0);
            assert!(y.max_abs() == 0.0, "{:?} not zero at init", kind);
            let dw = tt.delta_w(2, 1, 1);
            assert!(dw.max_abs() == 0.0);
        }
    }

    #[test]
    fn apply_matches_delta_w() {
        prop_check("apply == x·ΔW·α", 12, |rng, case| {
            let kind = [MetaTtKind::FourD, MetaTtKind::FiveD, MetaTtKind::FourPlusOneD]
                [case % 3];
            let init = InitStrategy {
                cores: vec![super::super::init::CoreInit::Normal; kind.order()],
            };
            let tt = MetaTt::new(kind, dims4(), 3, 0.7, &init, rng);
            let x = Tensor::randn(&[4, 16], 1.0, rng);
            let (l, m, t) = (rng.uniform_usize(3), rng.uniform_usize(2), rng.uniform_usize(3));
            let got = tt.apply(&x, l, m, t);
            let want = x.matmul(&tt.delta_w(l, m, t)).scale(0.7);
            let err = rel_err(&got, &want);
            if err < 1e-4 {
                Ok(())
            } else {
                Err(format!("{:?} err {err}", kind))
            }
        });
    }

    #[test]
    fn param_count_matches_paper_formula_4d() {
        // MetaTT-4D: 2Dr + (L + M) r^2 with D_in = D_out = D.
        let mut rng = Pcg64::new(2);
        let dims = dims4();
        let r = 4;
        let tt = MetaTt::new_default(MetaTtKind::FourD, dims, r, 1.0, &mut rng);
        let want = 2 * dims.d_in * r + (dims.layers + dims.matrices) * r * r;
        assert_eq!(tt.param_count(), want);
    }

    #[test]
    fn param_count_matches_paper_formula_5d() {
        // MetaTT-5D: (D + D/H) r + (L + M + H) r^2.
        let mut rng = Pcg64::new(3);
        let dims = dims4();
        let r = 4;
        let tt = MetaTt::new_default(MetaTtKind::FiveD, dims, r, 1.0, &mut rng);
        let want = (dims.d_in + dims.d_out / dims.heads) * r
            + (dims.layers + dims.matrices + dims.heads) * r * r;
        assert_eq!(tt.param_count(), want);
    }

    #[test]
    fn task_core_distinguishes_tasks() {
        let mut rng = Pcg64::new(4);
        let init = InitStrategy::from_code("no-no-no-no-no").unwrap();
        let tt = MetaTt::new(MetaTtKind::FourPlusOneD, dims4(), 3, 1.0, &init, &mut rng);
        let a = tt.delta_w(0, 0, 0);
        let b = tt.delta_w(0, 0, 2);
        assert!(rel_err(&a, &b) > 1e-3, "different tasks must give different ΔW");
    }

    #[test]
    fn export_import_roundtrip() {
        let mut rng = Pcg64::new(5);
        let init = InitStrategy::from_code("no-no-no-no").unwrap();
        let tt0 = MetaTt::new(MetaTtKind::FourD, dims4(), 3, 1.0, &init, &mut rng);
        let exported = tt0.export_cores();
        assert_eq!(exported[0].shape(), &[16, 3]); // (D, r)
        assert_eq!(exported[1].shape(), &[3, 3, 3]); // (L, r, r)
        assert_eq!(exported[3].shape(), &[3, 16]); // (r, D)
        let mut tt1 = MetaTt::new_default(MetaTtKind::FourD, dims4(), 3, 1.0, &mut rng);
        tt1.import_cores(&exported);
        for k in 0..4 {
            assert_eq!(tt0.chain.core(k), tt1.chain.core(k), "core {k}");
        }
    }

    #[test]
    fn folded_serving_form_matches_apply_all_families_and_tasks() {
        // Serving-parity pin for EVERY adapter family and EVERY task index
        // (the serving engine folds lazily per task, so no (family, task)
        // combination may drift from the trained apply path).
        let mut rng = Pcg64::new(6);
        let dims = dims4();
        for kind in [MetaTtKind::FourD, MetaTtKind::FiveD, MetaTtKind::FourPlusOneD] {
            let init = InitStrategy {
                cores: vec![super::super::init::CoreInit::Normal; kind.order()],
            };
            let tt = MetaTt::new(kind, dims, 3, 1.3, &init, &mut rng);
            let x = Tensor::randn(&[4, 16], 1.0, &mut rng);
            for task in 0..dims.tasks {
                let folded = tt.fold_for_serving(task);
                assert_eq!(folded.len(), dims.layers, "{kind:?}");
                for (l, row) in folded.iter().enumerate() {
                    assert_eq!(row.len(), dims.matrices, "{kind:?} l={l}");
                    for (m, (a, b)) in row.iter().enumerate() {
                        // Uniform serving shape contract: A is (D_in × r),
                        // B is (r × D_out) for every family.
                        assert_eq!(a.shape()[0], dims.d_in, "{kind:?}");
                        assert_eq!(b.shape()[1], dims.d_out, "{kind:?}");
                        assert_eq!(a.shape()[1], b.shape()[0], "{kind:?}");
                        let got = x.matmul(a).matmul(b);
                        let want = tt.apply(&x, l, m, task);
                        let err = rel_err(&got, &want);
                        assert!(err < 1e-4, "{kind:?} t={task} l={l} m={m}: {err}");
                    }
                }
            }
            // Task-free families fold identically for every task index.
            if kind != MetaTtKind::FourPlusOneD {
                assert_eq!(tt.distinct_tasks(), 1);
                let f0 = tt.fold_for_serving(0);
                let f2 = tt.fold_for_serving(2);
                for l in 0..dims.layers {
                    for m in 0..dims.matrices {
                        assert_eq!(f0[l][m], f2[l][m], "{kind:?} fold must ignore task");
                    }
                }
            } else {
                assert_eq!(tt.distinct_tasks(), dims.tasks);
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn fold_rejects_out_of_range_task_for_task_core() {
        let mut rng = Pcg64::new(7);
        let tt = MetaTt::new_default(MetaTtKind::FourPlusOneD, dims4(), 3, 1.0, &mut rng);
        let _ = tt.fold_for_serving(99);
    }
}
