//! Adapter zoo: parameter layouts, initializations and analytic counts for
//! MetaTT and every baseline the paper compares against (Table 1).
//!
//! Each adapter is described by an [`AdapterSpec`] that fixes, *identically
//! on the rust and python sides*, the ordered list of trainable arrays
//! (name + shape) crossing the HLO boundary. The rust coordinator builds the
//! initial host tensors here, feeds them to the AOT train-step, and applies
//! optimizer updates to the returned gradients; `python/compile/model.py`
//! declares the same layout when tracing.
//!
//! Analytic parameter counts implement the closed forms of paper §2.4 and
//! are checked against the constructed tensors in tests and in the
//! `complexity_table` bench.

use crate::tensor::Tensor;
use crate::tt::{InitStrategy, MetaTt, MetaTtKind};
use crate::util::rng::Pcg64;

/// Which adapter family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdapterKind {
    /// MetaTT-4D / 5D / (4+1)D — the paper's contribution.
    MetaTt(MetaTtKind),
    /// Per-(layer, matrix) LoRA [Hu+21].
    LoRa,
    /// VeRA [KBA24]: frozen shared random A, B; trainable per-matrix scaling
    /// vectors d (rank-sized) and b (output-sized).
    VeRa,
    /// LoTR [Ber+24]: shared U, V; per-(layer, matrix) r×r core.
    LoTr,
    /// Full fine-tuning of every encoder weight (upper baseline; also the
    /// pretraining path).
    Full,
}

impl AdapterKind {
    pub fn name(&self) -> String {
        match self {
            AdapterKind::MetaTt(k) => k.name().to_string(),
            AdapterKind::LoRa => "lora".into(),
            AdapterKind::VeRa => "vera".into(),
            AdapterKind::LoTr => "lotr".into(),
            AdapterKind::Full => "full".into(),
        }
    }

    pub fn from_name(s: &str) -> Result<AdapterKind, String> {
        match s {
            "lora" => Ok(AdapterKind::LoRa),
            "vera" => Ok(AdapterKind::VeRa),
            "lotr" => Ok(AdapterKind::LoTr),
            "full" => Ok(AdapterKind::Full),
            other => MetaTtKind::from_name(other).map(AdapterKind::MetaTt),
        }
    }
}

/// Transformer dimensions an adapter needs to size itself.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelDims {
    /// Hidden size D (= D_in = D_out for attention projections).
    pub hidden: usize,
    /// Encoder layers L.
    pub layers: usize,
    /// Attention heads H.
    pub heads: usize,
    /// Adapted projection matrices per layer M (Q,V → 2, paper App. A.2).
    pub matrices: usize,
    /// Tasks T (MTL only; 1 otherwise).
    pub tasks: usize,
    /// Vocab size (Full/pretraining counting only).
    pub vocab: usize,
    /// MLP inner dim (Full counting only; BERT-family: 4·hidden).
    pub ffn: usize,
    /// Max sequence length (position table, Full counting only).
    pub max_seq: usize,
}

impl ModelDims {
    /// RoBERTa-Base dims (analytic complexity experiments).
    pub fn roberta_base() -> ModelDims {
        ModelDims {
            hidden: 768,
            layers: 12,
            heads: 12,
            matrices: 2,
            tasks: 1,
            vocab: 50_265,
            ffn: 3_072,
            max_seq: 512,
        }
    }

    /// RoBERTa-Large dims.
    pub fn roberta_large() -> ModelDims {
        ModelDims {
            hidden: 1_024,
            layers: 24,
            heads: 16,
            matrices: 2,
            tasks: 1,
            vocab: 50_265,
            ffn: 4_096,
            max_seq: 512,
        }
    }

    /// Encoder parameter count (embeddings + attention + MLP + layernorms +
    /// pooler-free), the "FT" row denominator in Table 1.
    pub fn encoder_param_count(&self) -> usize {
        let d = self.hidden;
        let emb = self.vocab * d + self.max_seq * d + 2 * d; // tok + pos + emb-LN
        let attn = 4 * (d * d + d); // QKVO + biases
        let mlp = d * self.ffn + self.ffn + self.ffn * d + d;
        let lns = 2 * (2 * d);
        emb + self.layers * (attn + mlp + lns)
    }
}

/// One trainable array crossing the HLO boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A fully-specified adapter configuration.
#[derive(Clone, Debug)]
pub struct AdapterSpec {
    pub kind: AdapterKind,
    pub rank: usize,
    /// Scaling α (paper Eq. 5; grid {0.5, 4} in Appendix D).
    pub alpha: f32,
    pub dims: ModelDims,
}

impl AdapterSpec {
    pub fn new(kind: AdapterKind, rank: usize, alpha: f32, dims: ModelDims) -> AdapterSpec {
        AdapterSpec { kind, rank, alpha, dims }
    }

    /// Ordered trainable-array layout — MUST match python `model.py`'s
    /// `adapter_param_specs` exactly (names, shapes, order).
    pub fn param_specs(&self) -> Vec<ParamSpec> {
        let d = self.dims.hidden;
        let (l, m, h, t, r) = (
            self.dims.layers,
            self.dims.matrices,
            self.dims.heads,
            self.dims.tasks,
            self.rank,
        );
        let p = |name: &str, shape: &[usize]| ParamSpec {
            name: name.into(),
            shape: shape.to_vec(),
        };
        match self.kind {
            AdapterKind::MetaTt(MetaTtKind::FourD) => vec![
                p("g1", &[d, r]),
                p("g2", &[l, r, r]),
                p("g3", &[m, r, r]),
                p("g4", &[r, d]),
            ],
            AdapterKind::MetaTt(MetaTtKind::FiveD) => vec![
                p("g1", &[d, r]),
                p("g2", &[l, r, r]),
                p("g3", &[m, r, r]),
                p("g4", &[h, r, r]),
                p("g5", &[r, d / h]),
            ],
            AdapterKind::MetaTt(MetaTtKind::FourPlusOneD) => vec![
                p("g1", &[d, r]),
                p("g2", &[l, r, r]),
                p("g3", &[t, r, r]),
                p("g4", &[m, r, r]),
                p("g5", &[r, d]),
            ],
            AdapterKind::LoRa => vec![
                p("lora_a", &[l, m, d, r]),
                p("lora_b", &[l, m, r, d]),
            ],
            AdapterKind::VeRa => vec![
                // Frozen A (d×r), B (r×d) are baked into the HLO as
                // seed-fixed constants; trainable are the scaling vectors.
                p("vera_d", &[l, m, r]),
                p("vera_b", &[l, m, d]),
            ],
            AdapterKind::LoTr => vec![
                p("lotr_u", &[d, r]),
                p("lotr_s", &[l, m, r, r]),
                p("lotr_v", &[r, d]),
            ],
            AdapterKind::Full => vec![], // full FT trains the frozen set itself
        }
    }

    /// Exact trainable parameter count.
    pub fn param_count(&self) -> usize {
        match self.kind {
            AdapterKind::Full => self.dims.encoder_param_count(),
            _ => self.param_specs().iter().map(|s| s.numel()).sum(),
        }
    }

    /// Closed-form count from paper §2.4 (checked == `param_count` in
    /// tests; `Full`/`VeRA` use their published forms).
    pub fn paper_formula_count(&self) -> usize {
        let d = self.dims.hidden;
        let (l, m, h, t, r) = (
            self.dims.layers,
            self.dims.matrices,
            self.dims.heads,
            self.dims.tasks,
            self.rank,
        );
        match self.kind {
            AdapterKind::MetaTt(MetaTtKind::FourD) => 2 * d * r + (l + m) * r * r,
            AdapterKind::MetaTt(MetaTtKind::FiveD) => (d + d / h) * r + (l + m + h) * r * r,
            AdapterKind::MetaTt(MetaTtKind::FourPlusOneD) => {
                2 * d * r + (l + m + t) * r * r
            }
            AdapterKind::LoRa => 2 * l * m * d * r,
            AdapterKind::VeRa => l * m * (d + r),
            AdapterKind::LoTr => 2 * d * r + l * m * r * r,
            AdapterKind::Full => self.dims.encoder_param_count(),
        }
    }

    /// Build the initial trainable tensors (export layout), matching the
    /// paper's init rules: MetaTT ze-id-…; LoRA A ~ N(0, 1/√D), B = 0;
    /// VeRA d = 0.1, b = 0; LoTR U, V ~ N(0, 1/√D), S = 0.
    pub fn init_params(&self, rng: &mut Pcg64) -> Vec<Tensor> {
        self.init_params_with(rng, None)
    }

    /// Like [`init_params`] but with an explicit MetaTT init strategy
    /// (Figure 3 ablation).
    pub fn init_params_with(
        &self,
        rng: &mut Pcg64,
        metatt_init: Option<&InitStrategy>,
    ) -> Vec<Tensor> {
        let d = self.dims.hidden;
        let specs = self.param_specs();
        match self.kind {
            AdapterKind::MetaTt(kind) => {
                let tt = self.build_metatt_with(rng, metatt_init);
                debug_assert_eq!(kind, match self.kind {
                    AdapterKind::MetaTt(k) => k,
                    _ => unreachable!(),
                });
                tt.export_cores()
            }
            AdapterKind::LoRa => {
                let std = 1.0 / (d as f32).sqrt();
                vec![
                    Tensor::randn(&specs[0].shape, std, rng),
                    Tensor::zeros(&specs[1].shape),
                ]
            }
            AdapterKind::VeRa => vec![
                Tensor::full(&specs[0].shape, 0.1),
                Tensor::zeros(&specs[1].shape),
            ],
            AdapterKind::LoTr => {
                let std = 1.0 / (d as f32).sqrt();
                vec![
                    Tensor::randn(&specs[0].shape, std, rng),
                    Tensor::zeros(&specs[1].shape),
                    Tensor::randn(&specs[2].shape, std, rng),
                ]
            }
            AdapterKind::Full => vec![],
        }
    }

    /// Construct the host-side MetaTT object for this spec (panics for
    /// non-MetaTT kinds). Used by the DMRG scheduler, which needs the chain
    /// form for sweeps.
    pub fn build_metatt(&self, rng: &mut Pcg64) -> MetaTt {
        self.build_metatt_with(rng, None)
    }

    pub fn build_metatt_with(
        &self,
        rng: &mut Pcg64,
        init: Option<&InitStrategy>,
    ) -> MetaTt {
        let kind = match self.kind {
            AdapterKind::MetaTt(k) => k,
            other => panic!("build_metatt on non-MetaTT adapter {:?}", other),
        };
        let dims = crate::tt::MetaTt::dims_from_model(kind, &self.dims);
        match init {
            Some(s) => MetaTt::new(kind, dims, self.rank, self.alpha, s, rng),
            None => MetaTt::new_default(kind, dims, self.rank, self.alpha, rng),
        }
    }

    /// Compression factor vs LoRA at the same rank (paper abstract: "between
    /// 20x and 2x less parameters").
    pub fn compression_vs_lora(&self) -> f64 {
        let lora = AdapterSpec::new(AdapterKind::LoRa, self.rank, self.alpha, self.dims);
        lora.param_count() as f64 / self.param_count() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dims() -> ModelDims {
        ModelDims {
            hidden: 128,
            layers: 4,
            heads: 4,
            matrices: 2,
            tasks: 3,
            vocab: 1024,
            ffn: 512,
            max_seq: 64,
        }
    }

    #[test]
    fn constructed_counts_match_paper_formulas() {
        for dims in [tiny_dims(), ModelDims::roberta_base(), ModelDims::roberta_large()] {
            for rank in [4, 8, 16] {
                for kind in [
                    AdapterKind::MetaTt(MetaTtKind::FourD),
                    AdapterKind::MetaTt(MetaTtKind::FiveD),
                    AdapterKind::MetaTt(MetaTtKind::FourPlusOneD),
                    AdapterKind::LoRa,
                    AdapterKind::VeRa,
                    AdapterKind::LoTr,
                ] {
                    let spec = AdapterSpec::new(kind, rank, 1.0, dims);
                    assert_eq!(
                        spec.param_count(),
                        spec.paper_formula_count(),
                        "{:?} rank {rank} dims {:?}",
                        kind,
                        dims.hidden
                    );
                }
            }
        }
    }

    #[test]
    fn table1_parameter_regime_reproduced() {
        // Paper Table 1, RoBERTa-Base: LoRA r=8 ≈ 295k; MetaTT-4D r=8 ≈ 13k;
        // r=24 ≈ 45k; r=64 ≈ 156k; MetaTT-5D r=64 ≈ 160k; LoTR r=40 ≈ 100k.
        let base = ModelDims::roberta_base();
        let count = |kind, rank| AdapterSpec::new(kind, rank, 1.0, base).param_count();
        assert_eq!(count(AdapterKind::LoRa, 8), 294_912); // ≈295k ✓
        assert_eq!(count(AdapterKind::MetaTt(MetaTtKind::FourD), 8), 13_184); // ≈13k ✓
        assert_eq!(count(AdapterKind::MetaTt(MetaTtKind::FourD), 24), 44_928); // ≈45k ✓
        assert_eq!(count(AdapterKind::MetaTt(MetaTtKind::FourD), 64), 155_648); // ≈156k ✓
        let c5 = count(AdapterKind::MetaTt(MetaTtKind::FiveD), 64);
        assert!((155_000..170_000).contains(&c5), "5D r=64: {c5}"); // ≈160k ✓
        let lotr40 = count(AdapterKind::LoTr, 40);
        assert!((99_000..101_000).contains(&lotr40), "LoTR r=40: {lotr40}"); // ≈100k ✓
    }

    #[test]
    fn table1_large_regime_reproduced() {
        // RoBERTa-Large: LoRA r=8 ≈ 786k; MetaTT-4D r=16 ≈ 39k, r=32 ≈ 92k.
        let large = ModelDims::roberta_large();
        let count = |kind, rank| AdapterSpec::new(kind, rank, 1.0, large).param_count();
        assert_eq!(count(AdapterKind::LoRa, 8), 786_432);
        assert_eq!(count(AdapterKind::MetaTt(MetaTtKind::FourD), 16), 39_424);
        assert_eq!(count(AdapterKind::MetaTt(MetaTtKind::FourD), 32), 92_160);
    }

    #[test]
    fn init_params_match_specs_and_zero_condition() {
        let mut rng = Pcg64::new(1);
        for kind in [
            AdapterKind::MetaTt(MetaTtKind::FourD),
            AdapterKind::MetaTt(MetaTtKind::FiveD),
            AdapterKind::LoRa,
            AdapterKind::VeRa,
            AdapterKind::LoTr,
        ] {
            let spec = AdapterSpec::new(kind, 4, 1.0, tiny_dims());
            let params = spec.init_params(&mut rng);
            let specs = spec.param_specs();
            assert_eq!(params.len(), specs.len());
            for (p, s) in params.iter().zip(&specs) {
                assert_eq!(p.shape(), &s.shape[..], "{:?}/{}", kind, s.name);
            }
            // Zero-at-init: at least one factor of every product is zero.
            let any_zero = params.iter().any(|p| p.max_abs() == 0.0);
            assert!(any_zero, "{:?} must start as a zero map", kind);
        }
    }

    #[test]
    fn metatt_flat_len_matches_export() {
        let mut rng = Pcg64::new(2);
        let spec = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), 8, 1.0, tiny_dims());
        let tt = spec.build_metatt(&mut rng);
        assert_eq!(tt.param_count(), spec.param_count());
    }

    #[test]
    fn compression_vs_lora_regimes() {
        // Paper: 20x-2x fewer params than LoRA across the Table-1 grid.
        let base = ModelDims::roberta_base();
        let c8 = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), 8, 1.0, base)
            .compression_vs_lora();
        assert!(c8 > 20.0, "r=8 compression {c8}");
        let large = ModelDims::roberta_large();
        let c32 = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), 32, 1.0, large)
            .compression_vs_lora();
        assert!(c32 > 8.0, "large r=32 compression {c32}");
    }

    #[test]
    fn full_ft_count_is_model_scale() {
        // Table 1 lists FT at 125M (Base) / 355M (Large).
        let base = AdapterSpec::new(AdapterKind::Full, 0, 1.0, ModelDims::roberta_base());
        let c = base.param_count();
        assert!((80_000_000..130_000_000).contains(&c), "base FT count {c}");
        let large = AdapterSpec::new(AdapterKind::Full, 0, 1.0, ModelDims::roberta_large());
        let cl = large.param_count();
        assert!(cl > 2 * c, "large should be ≳2.8x base: {cl}");
    }
}
