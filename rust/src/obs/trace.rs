//! Lock-free per-thread ring-buffer span tracer.
//!
//! Recording is wait-free for the common case: each thread claims one
//! preallocated ring (CAS on an owner word, keyed by the address of a
//! `thread_local!` token) and then writes slots with plain relaxed stores —
//! single producer per ring, no allocation, no locks. A full ring wraps and
//! overwrites its oldest events; nothing ever blocks the serving tick. The
//! exact number of overwritten events is recoverable as
//! `written.saturating_sub(capacity)` per ring, surfaced by
//! [`Tracer::dropped`].
//!
//! Timestamps are caller-supplied monotonic microseconds (the engine's
//! `done_us` clock — see `Obs::epoch`), so spans line up with response
//! stamps and aggregate identically across 1-thread and N-thread runs.
//!
//! [`chrome_trace_json`] renders a snapshot as Chrome trace-event JSON
//! (`chrome://tracing` / Perfetto): tick spans become `"X"` complete events
//! with real durations, everything else an `"i"` instant event, one track
//! (`tid`) per ring.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Every event kind the serving stack can stamp. The numeric value is the
/// on-ring encoding; `0` is reserved for "empty slot".
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum EventCode {
    /// Request admitted to the queue. `a` = request id, `b` = task.
    Admit = 1,
    /// Request drained into a batch. `a` = request id, `b` = task.
    BatchFormed = 2,
    /// Serve tick started. `a` = task, `b` = batch rows.
    TickStart = 3,
    /// Serve tick finished. `a` = task, `b` = tick-start µs (so the span
    /// duration is `ts_us - b`).
    TickEnd = 4,
    /// Response handed to the per-request channel. `a` = request id,
    /// `b` = task.
    ResponseWritten = 5,
    /// Request shed (deadline passed before compute). `a` = id, `b` = task.
    Shed = 6,
    /// Worker re-bound a fresh step after a failed batch. `a` = worker,
    /// `b` = restart count.
    WorkerRestart = 7,
    /// Failed batch re-inserted into the queue. `a` = task, `b` = rows.
    Requeue = 8,
    /// Request answered `Error` after repeated failures. `a` = id,
    /// `b` = task.
    Quarantine = 9,
    /// Injected slow tick (fault plan). `a` = slept µs, `b` = task.
    SlowTick = 10,
    /// Folded-adapter cache miss → fold + pack. `a` = task, `b` = bytes.
    CacheFold = 11,
    /// Folded-adapter LRU eviction. `a` = task, `b` = bytes freed.
    CacheEvict = 12,
    /// Checkpoint hot-swap installed a new generation. `a` = generation.
    HotSwap = 13,
    /// Shard health transition → Live. `a` = shard.
    ShardLive = 14,
    /// Shard health transition → Degraded. `a` = shard, `b` = fail streak.
    ShardDegraded = 15,
    /// Shard health transition → Down. `a` = shard.
    ShardDown = 16,
    /// Down shard's queue drained into a survivor. `a` = dead shard,
    /// `b` = requests moved.
    FailoverDrain = 17,
    /// Work stolen between replicas. `a` = (from << 32) | to, `b` = moved.
    WorkSteal = 18,
    /// Checkpoint written. `a` = bytes, `b` = 1 if the write was torn by
    /// fault injection.
    CkptSave = 19,
    /// Checkpoint loaded. `a` = bytes, `b` = tensors.
    CkptLoad = 20,
    /// Request displaced by admission control. `a` = id, `b` = task.
    Displaced = 21,
}

impl EventCode {
    pub(crate) fn from_u64(v: u64) -> Option<EventCode> {
        use EventCode::*;
        Some(match v {
            1 => Admit,
            2 => BatchFormed,
            3 => TickStart,
            4 => TickEnd,
            5 => ResponseWritten,
            6 => Shed,
            7 => WorkerRestart,
            8 => Requeue,
            9 => Quarantine,
            10 => SlowTick,
            11 => CacheFold,
            12 => CacheEvict,
            13 => HotSwap,
            14 => ShardLive,
            15 => ShardDegraded,
            16 => ShardDown,
            17 => FailoverDrain,
            18 => WorkSteal,
            19 => CkptSave,
            20 => CkptLoad,
            21 => Displaced,
            _ => return None,
        })
    }

    /// Stable span name used in the Chrome trace and in tests.
    pub fn name(self) -> &'static str {
        use EventCode::*;
        match self {
            Admit => "admit",
            BatchFormed => "batch_formed",
            TickStart => "tick_start",
            TickEnd => "tick",
            ResponseWritten => "response_written",
            Shed => "shed",
            WorkerRestart => "worker_restart",
            Requeue => "requeue",
            Quarantine => "quarantine",
            SlowTick => "slow_tick",
            CacheFold => "cache_fold",
            CacheEvict => "cache_evict",
            HotSwap => "hot_swap",
            ShardLive => "shard_live",
            ShardDegraded => "shard_degraded",
            ShardDown => "shard_down",
            FailoverDrain => "failover_drain",
            WorkSteal => "work_steal",
            CkptSave => "ckpt_save",
            CkptLoad => "ckpt_load",
            Displaced => "displaced",
        }
    }
}

/// One decoded event out of a ring snapshot.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub ts_us: u64,
    pub code: EventCode,
    pub a: u64,
    pub b: u64,
    /// Which ring (≈ thread) recorded it; becomes the Chrome `tid`.
    pub ring: usize,
}

struct Slot {
    ts: AtomicU64,
    code: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
}

impl Slot {
    fn empty() -> Slot {
        Slot {
            ts: AtomicU64::new(0),
            code: AtomicU64::new(0),
            a: AtomicU64::new(0),
            b: AtomicU64::new(0),
        }
    }
}

struct Ring {
    /// 0 = unclaimed; otherwise the claiming thread's token address.
    owner: AtomicUsize,
    /// Total events ever written; index of the next slot is `written % cap`.
    written: AtomicU64,
    slots: Box<[Slot]>,
}

thread_local! {
    /// Address doubles as a per-thread identity: unique among live threads,
    /// stable for the thread's lifetime (a dead thread's ring is simply
    /// inherited by whichever new thread lands on the same address).
    static THREAD_TOKEN: u8 = const { 0 };
    /// (tracer address, ring index) — skips the claim scan on the hot path.
    static RING_HINT: Cell<(usize, usize)> = const { Cell::new((0, usize::MAX)) };
}

fn thread_key() -> usize {
    THREAD_TOKEN.with(|t| t as *const u8 as usize)
}

/// Fixed pool of per-thread rings. Disarmed tracers are built with zero
/// rings and cost nothing beyond the struct itself.
pub struct Tracer {
    rings: Box<[Ring]>,
    cap: usize,
    /// Events from threads that found every ring claimed.
    unclaimed_drops: AtomicU64,
}

impl Tracer {
    pub fn new(rings: usize, slots_per_ring: usize) -> Tracer {
        let rings = (0..rings)
            .map(|_| Ring {
                owner: AtomicUsize::new(0),
                written: AtomicU64::new(0),
                slots: (0..slots_per_ring).map(|_| Slot::empty()).collect(),
            })
            .collect();
        Tracer { rings, cap: slots_per_ring, unclaimed_drops: AtomicU64::new(0) }
    }

    fn claim(&self) -> Option<&Ring> {
        let me = thread_key();
        let tracer_id = self as *const Tracer as usize;
        let (hinted_for, idx) = RING_HINT.with(Cell::get);
        if hinted_for == tracer_id && idx < self.rings.len() {
            let r = &self.rings[idx];
            if r.owner.load(Ordering::Relaxed) == me {
                return Some(r);
            }
        }
        for (i, r) in self.rings.iter().enumerate() {
            let owner = r.owner.load(Ordering::Relaxed);
            let mine = owner == me
                || (owner == 0
                    && r.owner
                        .compare_exchange(0, me, Ordering::AcqRel, Ordering::Relaxed)
                        .is_ok());
            if mine {
                RING_HINT.with(|h| h.set((tracer_id, i)));
                return Some(r);
            }
        }
        None
    }

    /// Record one event. Wait-free single-producer write into this thread's
    /// ring; wraps over the oldest event when full. Never allocates.
    pub fn record(&self, ts_us: u64, code: EventCode, a: u64, b: u64) {
        let Some(ring) = self.claim() else {
            self.unclaimed_drops.fetch_add(1, Ordering::Relaxed);
            return;
        };
        let n = ring.written.load(Ordering::Relaxed);
        let slot = &ring.slots[(n % self.cap as u64) as usize];
        slot.ts.store(ts_us, Ordering::Relaxed);
        slot.code.store(code as u64, Ordering::Relaxed);
        slot.a.store(a, Ordering::Relaxed);
        slot.b.store(b, Ordering::Relaxed);
        ring.written.store(n + 1, Ordering::Release);
    }

    /// Decode the surviving events out of every ring, oldest first, merged
    /// and sorted by timestamp. Intended for post-run export (writers
    /// quiesced); a concurrent snapshot is safe but may catch a slot
    /// mid-overwrite.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for (ri, r) in self.rings.iter().enumerate() {
            let n = r.written.load(Ordering::Acquire);
            let live = n.min(self.cap as u64);
            for k in (n - live)..n {
                let s = &r.slots[(k % self.cap as u64) as usize];
                if let Some(code) = EventCode::from_u64(s.code.load(Ordering::Relaxed)) {
                    out.push(TraceEvent {
                        ts_us: s.ts.load(Ordering::Relaxed),
                        code,
                        a: s.a.load(Ordering::Relaxed),
                        b: s.b.load(Ordering::Relaxed),
                        ring: ri,
                    });
                }
            }
        }
        out.sort_by_key(|e| e.ts_us);
        out
    }

    /// Total events ever recorded (including ones since overwritten).
    pub fn recorded(&self) -> u64 {
        self.rings.iter().map(|r| r.written.load(Ordering::Acquire)).sum()
    }

    /// Exact number of events lost: ring wraparound overwrites (oldest
    /// first) plus records from threads that could not claim a ring.
    pub fn dropped(&self) -> u64 {
        let wrapped: u64 = self
            .rings
            .iter()
            .map(|r| r.written.load(Ordering::Acquire).saturating_sub(self.cap as u64))
            .sum();
        wrapped + self.unclaimed_drops.load(Ordering::Relaxed)
    }

    pub fn ring_count(&self) -> usize {
        self.rings.len()
    }

    pub fn ring_capacity(&self) -> usize {
        self.cap
    }
}

/// Render a snapshot as Chrome trace-event JSON (the `traceEvents` array
/// format accepted by `chrome://tracing` and Perfetto). [`EventCode::TickEnd`]
/// events carry their start timestamp in `b` and become `"X"` complete
/// events with a real duration; everything else is a thread-scoped `"i"`
/// instant event.
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    let mut out = String::with_capacity(64 + events.len() * 96);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let name = e.code.name();
        match e.code {
            EventCode::TickEnd => {
                let start = e.b.min(e.ts_us);
                out.push_str(&format!(
                    "{{\"name\":\"{}\",\"cat\":\"serve\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                     \"pid\":0,\"tid\":{},\"args\":{{\"task\":{}}}}}",
                    name,
                    start,
                    e.ts_us - start,
                    e.ring,
                    e.a
                ));
            }
            _ => {
                out.push_str(&format!(
                    "{{\"name\":\"{}\",\"cat\":\"serve\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{},\
                     \"pid\":0,\"tid\":{},\"args\":{{\"a\":{},\"b\":{}}}}}",
                    name, e.ts_us, e.ring, e.a, e.b
                ));
            }
        }
    }
    out.push_str("]}");
    out
}
