//! Zero-overhead observability for the serving stack (PR 10).
//!
//! Modeled on the `FaultPlan` pattern (`util::fault`): an [`Obs`] handle is
//! threaded through `EngineConfig` and armed via `--trace` /
//! `METATT_TRACE`. **When unarmed, every hook is a single relaxed atomic
//! load** and an early return — no allocation, no fence, no lock — so the
//! zero-alloc warmed serving tick is untouched (pinned in
//! `tests/alloc_regression.rs`). Three pieces:
//!
//! * [`trace::Tracer`] — lock-free per-thread ring-buffer span tracer
//!   stamping every request's lifecycle (admit → batch-formed → tick-start
//!   → tick-end → response-written) plus engine/router/cache/checkpoint
//!   events, exportable as Chrome trace-event JSON (`--trace-out`).
//! * [`metrics::Registry`] — counters, gauges, and fixed-boundary
//!   log-linear histograms with per-task/per-shard labels; `EngineStats`
//!   is absorbed as one producer among several at exposition time.
//! * Exposition — `ServeTarget::metrics_text` renders a Prometheus-style
//!   snapshot served live over the MTS1 `STAT` admin frame and dumped
//!   periodically as JSON via `--metrics-out`.
//!
//! All timestamps are µs on the engine's `done_us` clock: the engine and
//! router copy [`Obs::epoch`] at construction, so span timestamps, stage
//! stamps in `Response`, and `done_us` are directly comparable.
//!
//! Free functions without an engine handle (checkpoint save/load) report
//! through a process-global handle installed by [`set_global`]; its
//! unarmed cost is the same single relaxed load.

pub mod metrics;
pub mod trace;

pub use metrics::{bucket_bound, bucket_index, Counter, Gauge, Histogram, Registry, BUCKETS};
pub use trace::{chrome_trace_json, EventCode, TraceEvent, Tracer};

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::Instant;

/// Default ring pool: 16 threads × 8192 events ≈ 4 MiB, allocated only
/// when armed.
pub const DEFAULT_RINGS: usize = 16;
pub const DEFAULT_RING_SLOTS: usize = 8192;

/// Always-on protocol error counters for the TCP front-end. These sit on
/// cold error paths, so they count even when tracing is unarmed — errors
/// must never vanish just because nobody asked for spans.
pub struct NetCounters {
    /// Connections rejected for a bad `MTS1` magic.
    pub bad_magic: Arc<Counter>,
    /// Frames whose body failed to decode as a request.
    pub bad_frames: Arc<Counter>,
    /// Frames rejected for exceeding `MAX_FRAME`.
    pub oversized_frames: Arc<Counter>,
    /// Connections torn down by an I/O or protocol error.
    pub dropped_conns: Arc<Counter>,
    /// `STAT` admin frames served.
    pub stat_frames: Arc<Counter>,
}

impl NetCounters {
    fn new(reg: &Registry) -> NetCounters {
        NetCounters {
            bad_magic: reg.counter(
                "metatt_net_bad_magic_total",
                "connections rejected for a bad MTS1 magic",
                "",
            ),
            bad_frames: reg.counter(
                "metatt_net_bad_frames_total",
                "frames whose body failed to decode",
                "",
            ),
            oversized_frames: reg.counter(
                "metatt_net_oversized_frames_total",
                "frames rejected for exceeding MAX_FRAME",
                "",
            ),
            dropped_conns: reg.counter(
                "metatt_net_dropped_conns_total",
                "connections torn down by an I/O or protocol error",
                "",
            ),
            stat_frames: reg.counter("metatt_net_stat_frames_total", "STAT admin frames served", ""),
        }
    }
}

/// Armed-path stage histograms (µs), observed per request at
/// response-write time. Fixed log-linear buckets: see [`metrics`].
pub struct StageHists {
    pub queue_wait_us: Arc<Histogram>,
    pub batch_wait_us: Arc<Histogram>,
    pub compute_us: Arc<Histogram>,
    pub respond_us: Arc<Histogram>,
    pub tick_us: Arc<Histogram>,
}

impl StageHists {
    fn new(reg: &Registry) -> StageHists {
        StageHists {
            queue_wait_us: reg.histogram(
                "metatt_stage_queue_wait_us",
                "admission to batch-formed",
                "",
            ),
            batch_wait_us: reg.histogram(
                "metatt_stage_batch_wait_us",
                "batch-formed to tick-start",
                "",
            ),
            compute_us: reg.histogram("metatt_stage_compute_us", "tick-start to tick-end", ""),
            respond_us: reg.histogram(
                "metatt_stage_respond_us",
                "tick-end to response-written",
                "",
            ),
            tick_us: reg.histogram("metatt_stage_tick_us", "whole serve tick", ""),
        }
    }
}

/// The observability handle. Cheap to construct disarmed (no rings); one
/// per deployment, shared by every shard through `EngineConfig::obs`.
pub struct Obs {
    armed: AtomicBool,
    epoch: Instant,
    tracer: Tracer,
    registry: Registry,
    pub net: NetCounters,
    pub stages: StageHists,
}

impl Default for Obs {
    fn default() -> Obs {
        Obs::new(false)
    }
}

impl std::fmt::Debug for Obs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Obs")
            .field("armed", &self.armed())
            .field("rings", &self.tracer.ring_count())
            .field("ring_capacity", &self.tracer.ring_capacity())
            .field("recorded", &self.tracer.recorded())
            .field("dropped", &self.tracer.dropped())
            .finish()
    }
}

impl Obs {
    /// `armed = false` builds a zero-ring tracer: hooks early-return on a
    /// relaxed load and nothing else exists to pay for.
    pub fn new(armed: bool) -> Obs {
        let rings = if armed { DEFAULT_RINGS } else { 0 };
        Obs::with_rings(armed, rings, DEFAULT_RING_SLOTS)
    }

    /// Explicit ring geometry (tests use tiny rings to force wraparound).
    pub fn with_rings(armed: bool, rings: usize, slots_per_ring: usize) -> Obs {
        let registry = Registry::new();
        let net = NetCounters::new(&registry);
        let stages = StageHists::new(&registry);
        Obs {
            armed: AtomicBool::new(armed),
            epoch: Instant::now(),
            tracer: Tracer::new(rings, slots_per_ring),
            registry,
            net,
            stages,
        }
    }

    /// `true` when the CLI flag is set or `METATT_TRACE` is a non-empty
    /// value other than `0`.
    pub fn armed_from_env(flag: bool) -> bool {
        flag || std::env::var("METATT_TRACE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
    }

    /// The single relaxed load every hook starts (and, unarmed, ends) with.
    #[inline]
    pub fn armed(&self) -> bool {
        self.armed.load(Ordering::Relaxed)
    }

    /// The µs-clock origin. The engine and router copy this at
    /// construction so `done_us`, stage stamps, and span timestamps share
    /// one clock.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Microseconds since [`Obs::epoch`].
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record an event stamped now. Unarmed: one relaxed load.
    #[inline]
    pub fn event(&self, code: EventCode, a: u64, b: u64) {
        if self.armed() {
            self.event_cold(code, a, b);
        }
    }

    #[cold]
    fn event_cold(&self, code: EventCode, a: u64, b: u64) {
        self.tracer.record(self.now_us(), code, a, b);
    }

    /// Record an event with a caller-supplied timestamp (reusing a stage
    /// stamp already taken on the engine clock). Unarmed: one relaxed load.
    #[inline]
    pub fn event_at(&self, ts_us: u64, code: EventCode, a: u64, b: u64) {
        if self.armed() {
            self.tracer.record(ts_us, code, a, b);
        }
    }

    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Snapshot → Chrome trace-event JSON (for `--trace-out`).
    pub fn chrome_trace(&self) -> String {
        chrome_trace_json(&self.tracer.snapshot())
    }

    pub fn write_chrome_trace(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.chrome_trace())
    }

    /// JSON snapshot of the registry plus tracer meta-fields: what
    /// `--metrics-out` rewrites once a second while serving.
    pub fn metrics_json(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = write!(
            out,
            "{{\"uptime_us\":{},\"armed\":{},\"trace_events\":{},\"trace_dropped\":{},\
             \"metrics\":",
            self.now_us(),
            self.armed(),
            self.tracer.recorded(),
            self.tracer.dropped()
        );
        self.registry.render_json(&mut out);
        out.push('}');
        out
    }

    /// Append the registry snapshot plus tracer meta-metrics in Prometheus
    /// text format. Callers (`ServeTarget::metrics_text`) prepend their own
    /// producer families (engine stats, cache stats, router health).
    pub fn render(&self, out: &mut String) {
        use std::fmt::Write;
        self.registry.render(out);
        let _ = writeln!(out, "# TYPE metatt_trace_armed gauge");
        let _ = writeln!(out, "metatt_trace_armed {}", u64::from(self.armed()));
        let _ = writeln!(out, "# TYPE metatt_trace_events_total counter");
        let _ = writeln!(out, "metatt_trace_events_total {}", self.tracer.recorded());
        let _ = writeln!(out, "# TYPE metatt_trace_dropped_total counter");
        let _ = writeln!(out, "metatt_trace_dropped_total {}", self.tracer.dropped());
    }
}

// ---------------------------------------------------------------------------
// Process-global handle for free functions (checkpoint save/load) that have
// no engine to hand them an Obs. Fast path is one relaxed load on a static.
// ---------------------------------------------------------------------------

static GLOBAL_ARMED: AtomicBool = AtomicBool::new(false);

fn global_cell() -> &'static RwLock<Option<Arc<Obs>>> {
    static CELL: OnceLock<RwLock<Option<Arc<Obs>>>> = OnceLock::new();
    CELL.get_or_init(|| RwLock::new(None))
}

/// Install (or clear, with `None`) the process-global handle. `serve` sets
/// this to the session's `Obs`; tests set and clear it around assertions.
pub fn set_global(obs: Option<Arc<Obs>>) {
    let armed = obs.as_ref().is_some_and(|o| o.armed());
    *global_cell().write().unwrap() = obs;
    GLOBAL_ARMED.store(armed, Ordering::Relaxed);
}

/// The currently installed global handle, if any.
pub fn global() -> Option<Arc<Obs>> {
    global_cell().read().unwrap().clone()
}

/// Record an event through the global handle. Unarmed (or none installed):
/// a single relaxed load on a static.
#[inline]
pub fn global_event(code: EventCode, a: u64, b: u64) {
    if GLOBAL_ARMED.load(Ordering::Relaxed) {
        global_event_cold(code, a, b);
    }
}

#[cold]
fn global_event_cold(code: EventCode, a: u64, b: u64) {
    if let Some(obs) = global() {
        obs.event(code, a, b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_obs_records_nothing() {
        let obs = Obs::new(false);
        obs.event(EventCode::Admit, 1, 0);
        obs.event_at(5, EventCode::TickStart, 0, 4);
        assert!(!obs.armed());
        assert_eq!(obs.tracer().recorded(), 0);
        assert_eq!(obs.tracer().dropped(), 0);
        assert!(obs.tracer().snapshot().is_empty());
    }

    #[test]
    fn armed_obs_round_trips_events() {
        let obs = Obs::with_rings(true, 2, 64);
        obs.event_at(10, EventCode::Admit, 7, 1);
        obs.event_at(20, EventCode::TickEnd, 1, 12);
        let events = obs.tracer().snapshot();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].code, EventCode::Admit);
        assert_eq!(events[0].a, 7);
        assert_eq!(events[1].ts_us, 20);
        let json = obs.chrome_trace();
        assert!(json.contains("\"traceEvents\""), "{json}");
        assert!(json.contains("\"name\":\"admit\""), "{json}");
        // TickEnd becomes a complete event with dur = ts - b.
        assert!(json.contains("\"ph\":\"X\"") && json.contains("\"dur\":8"), "{json}");
        assert!(crate::util::json::parse(&json).is_ok(), "chrome trace must parse");
    }

    #[test]
    fn global_handle_gates_on_armed() {
        // Disarmed global: events vanish, fast flag stays clear.
        let quiet = Arc::new(Obs::new(false));
        set_global(Some(quiet.clone()));
        global_event(EventCode::CkptSave, 1, 0);
        assert_eq!(quiet.tracer().recorded(), 0);
        // Armed global: events land. (Other tests in this binary may emit
        // global events concurrently — assert containment, not counts.)
        let loud = Arc::new(Obs::with_rings(true, 4, 64));
        set_global(Some(loud.clone()));
        global_event(EventCode::CkptSave, 123_456_789, 0);
        assert!(loud
            .tracer()
            .snapshot()
            .iter()
            .any(|e| e.code == EventCode::CkptSave && e.a == 123_456_789));
        set_global(None);
    }
}
