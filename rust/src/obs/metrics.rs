//! Metrics registry: counters, gauges, and log-linear bucket histograms
//! with a Prometheus-style text exposition.
//!
//! Histograms use **fixed** log-linear bucket boundaries (four linear
//! sub-buckets per power-of-two octave, values in µs): a value lands in the
//! same bucket no matter which thread observed it or how many threads were
//! running, so 1-thread and N-thread runs aggregate identically and records
//! from different runs can be merged bucket-by-bucket.
//!
//! Handles (`Arc<Counter>` etc.) are registered once — keyed by
//! `(name, labels)` — and cached by producers; the hot path is a plain
//! relaxed atomic add. Rendering walks the registry under its mutex, which
//! only ever contends with other renders and late registrations.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of histogram buckets. The last bucket is the +Inf catch-all.
/// 4 sub-buckets per octave covers [0, 2^25) µs (~33 s) with ≤ ~12%
/// relative bucket width before saturating.
pub const BUCKETS: usize = 96;

/// Fixed log-linear bucket index for a value in µs.
pub fn bucket_index(v: u64) -> usize {
    if v < 4 {
        return v as usize; // buckets 0..=3 hold exact values 0,1,2,3
    }
    let octave = 63 - v.leading_zeros() as usize; // floor(log2 v), >= 2
    let sub = ((v >> (octave - 2)) & 3) as usize; // top two bits below the lead
    (4 * (octave - 1) + sub).min(BUCKETS - 1)
}

/// Inclusive upper bound of bucket `i` (`u64::MAX` for the +Inf bucket).
pub fn bucket_bound(i: usize) -> u64 {
    if i < 4 {
        return i as u64;
    }
    if i >= BUCKETS - 1 {
        return u64::MAX;
    }
    let octave = i / 4 + 1;
    let sub = (i % 4) as u64;
    (1u64 << octave) + (sub + 1) * (1u64 << (octave - 2)) - 1
}

/// Monotone counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge.
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-boundary log-linear histogram (values in µs).
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn bucket(&self, i: usize) -> u64 {
        self.buckets[i].load(Ordering::Relaxed)
    }

    /// Bucket-resolution quantile estimate: the upper bound of the bucket
    /// holding the rank-`q` observation (0 when empty). `q` in [0, 1].
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for i in 0..BUCKETS {
            seen += self.bucket(i);
            if seen >= rank {
                let bound = bucket_bound(i);
                if bound == u64::MAX {
                    // +Inf bucket: fall back to the mean as a finite stand-in.
                    return self.sum() / n;
                }
                return bound;
            }
        }
        bucket_bound(BUCKETS - 2)
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

struct Entry {
    name: &'static str,
    help: &'static str,
    /// Pre-formatted label pairs, e.g. `task="0"`. Empty for no labels.
    labels: String,
    metric: Metric,
}

/// Get-or-create registry of named metrics; renders a Prometheus-style
/// text snapshot. Registration is construction-time; hot-path updates go
/// through the returned `Arc` handles and never touch the registry lock.
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn get_or_insert(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &str,
        make: impl FnOnce() -> Metric,
    ) -> Metric {
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.iter().find(|e| e.name == name && e.labels == labels) {
            return match &e.metric {
                Metric::Counter(c) => Metric::Counter(c.clone()),
                Metric::Gauge(g) => Metric::Gauge(g.clone()),
                Metric::Histogram(h) => Metric::Histogram(h.clone()),
            };
        }
        let metric = make();
        let handle = match &metric {
            Metric::Counter(c) => Metric::Counter(c.clone()),
            Metric::Gauge(g) => Metric::Gauge(g.clone()),
            Metric::Histogram(h) => Metric::Histogram(h.clone()),
        };
        entries.push(Entry { name, help, labels: labels.to_string(), metric });
        handle
    }

    /// Get-or-create a counter for `(name, labels)`.
    pub fn counter(&self, name: &'static str, help: &'static str, labels: &str) -> Arc<Counter> {
        match self.get_or_insert(name, help, labels, || Metric::Counter(Arc::default())) {
            Metric::Counter(c) => c,
            other => panic!("metric {name} already registered as {}", other.kind()),
        }
    }

    /// Get-or-create a gauge for `(name, labels)`.
    pub fn gauge(&self, name: &'static str, help: &'static str, labels: &str) -> Arc<Gauge> {
        match self.get_or_insert(name, help, labels, || Metric::Gauge(Arc::default())) {
            Metric::Gauge(g) => g,
            other => panic!("metric {name} already registered as {}", other.kind()),
        }
    }

    /// Get-or-create a histogram for `(name, labels)`.
    pub fn histogram(&self, name: &'static str, help: &'static str, labels: &str) -> Arc<Histogram> {
        match self.get_or_insert(name, help, labels, || Metric::Histogram(Arc::default())) {
            Metric::Histogram(h) => h,
            other => panic!("metric {name} already registered as {}", other.kind()),
        }
    }

    /// Append a JSON array snapshot (`--metrics-out`): one object per
    /// registered metric with name/labels/kind; counters and gauges carry
    /// `value`, histograms carry `count`/`sum`/`p50`/`p95`/`p99` (µs).
    pub fn render_json(&self, out: &mut String) {
        use std::fmt::Write;
        let entries = self.entries.lock().unwrap();
        let mut order: Vec<usize> = (0..entries.len()).collect();
        order.sort_by(|&a, &b| {
            (entries[a].name, entries[a].labels.as_str())
                .cmp(&(entries[b].name, entries[b].labels.as_str()))
        });
        out.push('[');
        for (k, &i) in order.iter().enumerate() {
            let e = &entries[i];
            if k > 0 {
                out.push(',');
            }
            let labels = e.labels.replace('\\', "\\\\").replace('"', "\\\"");
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"labels\":\"{}\",\"kind\":\"{}\",",
                e.name,
                labels,
                e.metric.kind()
            );
            match &e.metric {
                Metric::Counter(c) => {
                    let _ = write!(out, "\"value\":{}}}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = write!(out, "\"value\":{}}}", g.get());
                }
                Metric::Histogram(h) => {
                    let _ = write!(
                        out,
                        "\"count\":{},\"sum\":{},\"p50\":{},\"p95\":{},\"p99\":{}}}",
                        h.count(),
                        h.sum(),
                        h.quantile(0.5),
                        h.quantile(0.95),
                        h.quantile(0.99)
                    );
                }
            }
        }
        out.push(']');
    }

    /// Append a Prometheus text-format snapshot of every registered metric.
    pub fn render(&self, out: &mut String) {
        use std::fmt::Write;
        let entries = self.entries.lock().unwrap();
        // Stable order: by name, then label string, preserving insertion
        // order among equals. Emit # HELP/# TYPE once per family.
        let mut order: Vec<usize> = (0..entries.len()).collect();
        order.sort_by(|&a, &b| {
            (entries[a].name, entries[a].labels.as_str())
                .cmp(&(entries[b].name, entries[b].labels.as_str()))
        });
        let mut last_family = "";
        for &i in &order {
            let e = &entries[i];
            if e.name != last_family {
                if !e.help.is_empty() {
                    let _ = writeln!(out, "# HELP {} {}", e.name, e.help);
                }
                let _ = writeln!(out, "# TYPE {} {}", e.name, e.metric.kind());
                last_family = e.name;
            }
            match &e.metric {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "{}{} {}", e.name, brace(&e.labels), c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "{}{} {}", e.name, brace(&e.labels), g.get());
                }
                Metric::Histogram(h) => {
                    let mut cum = 0u64;
                    for b in 0..BUCKETS {
                        let n = h.bucket(b);
                        if n == 0 && b < BUCKETS - 1 {
                            cum += n;
                            continue; // keep the exposition compact
                        }
                        cum += n;
                        let le = bucket_bound(b);
                        let le = if le == u64::MAX {
                            "+Inf".to_string()
                        } else {
                            le.to_string()
                        };
                        let _ = writeln!(
                            out,
                            "{}_bucket{} {}",
                            e.name,
                            brace_with(&e.labels, &format!("le=\"{le}\"")),
                            cum
                        );
                    }
                    let _ = writeln!(out, "{}_sum{} {}", e.name, brace(&e.labels), h.sum());
                    let _ = writeln!(out, "{}_count{} {}", e.name, brace(&e.labels), h.count());
                }
            }
        }
    }
}

fn brace(labels: &str) -> String {
    if labels.is_empty() {
        String::new()
    } else {
        format!("{{{labels}}}")
    }
}

fn brace_with(labels: &str, extra: &str) -> String {
    if labels.is_empty() {
        format!("{{{extra}}}")
    } else {
        format!("{{{labels},{extra}}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_contiguous_and_monotone() {
        // Every value maps to exactly one bucket whose bound brackets it.
        let mut prev_bound = 0u64;
        for i in 0..BUCKETS - 1 {
            let b = bucket_bound(i);
            assert!(b >= prev_bound, "bucket {i} bound regressed");
            prev_bound = b;
        }
        for v in (0u64..4096).chain([1 << 13, 1 << 20, (1 << 25) + 5, u64::MAX / 2]) {
            let i = bucket_index(v);
            assert!(i < BUCKETS);
            if i > 0 {
                assert!(v > bucket_bound(i - 1), "v={v} below bucket {i} floor");
            }
            assert!(v <= bucket_bound(i), "v={v} above bucket {i} bound");
        }
    }

    #[test]
    fn bucket_index_is_thread_count_independent_by_construction() {
        // The same observations, split across two histograms (as if two
        // threads each observed half), merge to the same buckets as one.
        let one = Histogram::default();
        let a = Histogram::default();
        let b = Histogram::default();
        let vals = [0u64, 1, 3, 4, 7, 9, 100, 1000, 123_456, 40_000_000];
        for (k, &v) in vals.iter().enumerate() {
            one.observe(v);
            if k % 2 == 0 { a.observe(v) } else { b.observe(v) }
        }
        for i in 0..BUCKETS {
            assert_eq!(one.bucket(i), a.bucket(i) + b.bucket(i), "bucket {i}");
        }
        assert_eq!(one.sum(), a.sum() + b.sum());
    }

    #[test]
    fn histogram_quantile_brackets_observations() {
        let h = Histogram::default();
        for v in 1..=1000u64 {
            h.observe(v);
        }
        let p50 = h.quantile(0.5);
        assert!((400..=700).contains(&p50), "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((900..=1100).contains(&p99), "p99={p99}");
    }

    #[test]
    fn registry_renders_prometheus_text() {
        let r = Registry::new();
        let c = r.counter("metatt_test_total", "a test counter", "task=\"0\"");
        c.add(3);
        let c2 = r.counter("metatt_test_total", "a test counter", "task=\"1\"");
        c2.inc();
        let g = r.gauge("metatt_test_gauge", "", "");
        g.set(7);
        let h = r.histogram("metatt_test_us", "", "");
        h.observe(5);
        h.observe(5000);
        let mut out = String::new();
        r.render(&mut out);
        assert!(out.contains("# TYPE metatt_test_total counter"), "{out}");
        assert!(out.contains("metatt_test_total{task=\"0\"} 3"), "{out}");
        assert!(out.contains("metatt_test_total{task=\"1\"} 1"), "{out}");
        assert!(out.contains("metatt_test_gauge 7"), "{out}");
        assert!(out.contains("metatt_test_us_count 2"), "{out}");
        assert!(out.contains("le=\"+Inf\""), "{out}");
        // Same handle on re-registration.
        let again = r.counter("metatt_test_total", "a test counter", "task=\"0\"");
        again.inc();
        assert_eq!(c.get(), 4);
    }
}
