"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

This is THE core correctness signal of the compile path: the train-step
artifacts lower `ref.py` and the serve artifacts lower the Pallas kernels,
so kernel == ref is what makes the trained and served math the same
function. Includes a hypothesis sweep over shapes/dtypes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import tt_apply as k


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def assert_close(got, want, rel=1e-5):
    """Scale-aware closeness: the kernel and ref accumulate in different
    orders, so per-element error scales with the magnitude of the chain."""
    scale = float(np.abs(want).max()) or 1.0
    np.testing.assert_allclose(got, want, atol=rel * scale, rtol=1e-4)


class TestTtApply4d:
    def test_matches_ref_basic(self):
        kx, k1, km, k4 = keys(0, 4)
        x, g1 = rand(kx, (256, 64)), rand(k1, (64, 8))
        mid, g4 = rand(km, (8, 8)), rand(k4, (8, 64))
        got = k.tt_apply(x, g1, mid, g4, alpha=0.5)
        want = ref.tt_apply_ref(x, g1, mid, g4, alpha=0.5)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        n_blocks=st.integers(1, 4),
        blk=st.sampled_from([8, 32, 128]),
        d_in=st.sampled_from([16, 64, 256]),
        d_out=st.sampled_from([16, 64, 256]),
        r=st.integers(1, 32),
        alpha=st.sampled_from([0.5, 1.0, 4.0]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_shape_sweep(self, n_blocks, blk, d_in, d_out, r, alpha, seed):
        n = n_blocks * blk
        kx, k1, km, k4 = keys(seed, 4)
        x, g1 = rand(kx, (n, d_in)), rand(k1, (d_in, r))
        mid, g4 = rand(km, (r, r)), rand(k4, (r, d_out))
        got = k.tt_apply(x, g1, mid, g4, alpha=alpha, block_n=blk)
        want = ref.tt_apply_ref(x, g1, mid, g4, alpha=alpha)
        assert_close(got, want)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_bfloat16_inputs_accumulate_in_f32(self, seed):
        kx, k1, km, k4 = keys(seed, 4)
        x = rand(kx, (128, 64), jnp.bfloat16)
        g1 = rand(k1, (64, 8), jnp.bfloat16)
        mid, g4 = rand(km, (8, 8), jnp.bfloat16), rand(k4, (8, 64), jnp.bfloat16)
        got = k.tt_apply(x, g1, mid, g4, alpha=1.0).astype(jnp.float32)
        want = ref.tt_apply_ref(
            x.astype(jnp.float32), g1.astype(jnp.float32),
            mid.astype(jnp.float32), g4.astype(jnp.float32), alpha=1.0,
        )
        # bf16 storage: ~3 decimal digits.
        np.testing.assert_allclose(got, want, atol=0.25, rtol=0.1)

    def test_zero_g1_gives_zero_output(self):
        # The LoRA zero-at-init condition, paper §3.
        kx, km, k4 = keys(1, 3)
        x = rand(kx, (128, 32))
        g1 = jnp.zeros((32, 4))
        out = k.tt_apply(x, g1, rand(km, (4, 4)), rand(k4, (4, 32)), alpha=4.0)
        assert float(jnp.abs(out).max()) == 0.0

    def test_rejects_indivisible_batch(self):
        with pytest.raises(ValueError):
            k.tt_apply(
                jnp.zeros((100, 16)), jnp.zeros((16, 4)),
                jnp.zeros((4, 4)), jnp.zeros((4, 16)), 1.0, block_n=64,
            )


class TestTtApply5d:
    @settings(max_examples=15, deadline=None)
    @given(
        heads=st.sampled_from([2, 4, 8]),
        dh=st.sampled_from([8, 16]),
        r=st.integers(1, 16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, heads, dh, r, seed):
        d = heads * dh
        kx, k1, km, k4, k5 = keys(seed, 5)
        x, g1 = rand(kx, (64, d)), rand(k1, (d, r))
        mid = rand(km, (r, r))
        g4h, g5 = rand(k4, (heads, r, r)), rand(k5, (r, dh))
        got = k.tt_apply_5d(x, g1, mid, g4h, g5, alpha=0.5, block_n=32)
        want = ref.tt_apply_5d_ref(x, g1, mid, g4h, g5, alpha=0.5)
        assert_close(got, want)

    def test_head_blocks_are_independent(self):
        # Zeroing head h's core must zero exactly that output block.
        kx, k1, km, k4, k5 = keys(3, 5)
        h, r, dh = 4, 6, 8
        d = h * dh
        x, g1 = rand(kx, (32, d)), rand(k1, (d, r))
        mid, g5 = rand(km, (r, r)), rand(k5, (r, dh))
        g4h = rand(k4, (h, r, r))
        g4h = g4h.at[2].set(0.0)
        out = k.tt_apply_5d(x, g1, mid, g4h, g5, alpha=1.0, block_n=32)
        blocks = out.reshape(32, h, dh)
        assert float(jnp.abs(blocks[:, 2]).max()) == 0.0
        assert float(jnp.abs(blocks[:, 0]).max()) > 0.0


class TestLoraApply:
    @settings(max_examples=15, deadline=None)
    @given(
        d=st.sampled_from([32, 128]),
        r=st.integers(1, 32),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, d, r, seed):
        kx, ka, kb = keys(seed, 3)
        x, a, b = rand(kx, (128, d)), rand(ka, (d, r)), rand(kb, (r, d))
        got = k.lora_apply(x, a, b, alpha=2.0)
        want = ref.lora_apply_ref(x, a, b, alpha=2.0)
        assert_close(got, want)


class TestEquivalences:
    def test_tt_reduces_to_lora_with_identity_mid(self):
        # With mid = I, the TT chain is exactly a LoRA pair (A=G1, B=G4).
        kx, k1, k4 = keys(4, 3)
        x, g1, g4 = rand(kx, (64, 32)), rand(k1, (32, 8)), rand(k4, (8, 32))
        tt = k.tt_apply(x, g1, jnp.eye(8), g4, alpha=1.5)
        lora = k.lora_apply(x, g1, g4, alpha=1.5)
        np.testing.assert_allclose(tt, lora, atol=1e-5, rtol=1e-5)

    def test_alpha_is_linear_scaling(self):
        kx, k1, km, k4 = keys(5, 4)
        x, g1 = rand(kx, (64, 16)), rand(k1, (16, 4))
        mid, g4 = rand(km, (4, 4)), rand(k4, (4, 16))
        y1 = k.tt_apply(x, g1, mid, g4, alpha=1.0)
        y4 = k.tt_apply(x, g1, mid, g4, alpha=4.0)
        np.testing.assert_allclose(4.0 * y1, y4, atol=1e-5, rtol=1e-5)


class TestAnalyze:
    def test_vmem_fits_and_scales(self):
        a = k.analyze(4096, 1024, 64)
        assert a["vmem_frac"] < 0.25  # resident factors well inside VMEM
        small = k.analyze(4096, 1024, 8)
        assert small["arith_intensity"] < a["arith_intensity"]
        assert 0.0 < a["mxu_util"] <= 1.0

    def test_fused_chain_flops(self):
        a = k.analyze(128, 64, 8)
        # 2 * n * (d*r + r*r + r*d)
        assert a["flops"] == 2 * 128 * (64 * 8 + 64 + 8 * 64)
