"""L2 correctness: encoder forward, adapters, losses, step builders."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

PRESET = "tiny"
P = model.MODEL_PRESETS[PRESET]
B, S = 4, P["max_seq"]


def make_frozen(tasks=1, classes=2, seed=0):
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, shape in model.frozen_specs(PRESET, tasks, classes):
        key, sub = jax.random.split(key)
        if name.endswith("_g"):
            out[name] = jnp.ones(shape, jnp.float32)
        else:
            out[name] = jax.random.normal(sub, shape, jnp.float32) * 0.05
    return out


def make_trainable(adapter, rank, tasks=1, seed=1, zero_first=True):
    key = jax.random.PRNGKey(seed)
    out = {}
    specs = model.adapter_param_specs(adapter, PRESET, rank, tasks)
    for i, (name, shape) in enumerate(specs):
        key, sub = jax.random.split(key)
        out[name] = jax.random.normal(sub, shape, jnp.float32) * 0.3
        if zero_first and i == 0:
            out[name] = jnp.zeros(shape, jnp.float32)
    return out


def tokens_batch(seed=2):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (B, S), 4, P["vocab"])
    # CLS head + PAD tail like the rust batcher produces.
    toks = toks.at[:, 0].set(1)
    toks = toks.at[:, -4:].set(0)
    return toks


ADAPTERS = ["metatt4d", "metatt5d", "metatt4p1d", "lora", "vera", "lotr"]


class TestForward:
    def test_hidden_shape_and_finite(self):
        fz = make_frozen()
        tr = make_trainable("metatt4d", 8)
        h = model.encoder_forward(
            PRESET, "metatt4d", 8, 1.0, fz, tr, tokens_batch(), jnp.int32(0)
        )
        assert h.shape == (B, S, P["hidden"])
        assert bool(jnp.isfinite(h).all())

    @pytest.mark.parametrize("adapter", ADAPTERS)
    def test_zero_init_adapters_do_not_change_logits(self, adapter):
        # LoRA condition (paper §3): zero first factor => output == frozen model.
        tasks = 3 if adapter == "metatt4p1d" else 1
        fz = make_frozen(tasks=tasks)
        toks = tokens_batch()
        tr = make_trainable(adapter, 8, tasks=tasks, zero_first=True)
        base = model.task_logits(
            PRESET, "none", 8, 1.0, fz, {}, toks, jnp.int32(0)
        )
        with_adapter = model.task_logits(
            PRESET, adapter, 8, 1.0, fz, tr, toks, jnp.int32(0)
        )
        np.testing.assert_allclose(with_adapter, base, atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("adapter", ADAPTERS)
    def test_nonzero_adapters_change_logits(self, adapter):
        tasks = 3 if adapter == "metatt4p1d" else 1
        fz = make_frozen(tasks=tasks)
        toks = tokens_batch()
        tr = make_trainable(adapter, 8, tasks=tasks, zero_first=False)
        base = model.task_logits(PRESET, "none", 8, 1.0, fz, {}, toks, jnp.int32(0))
        out = model.task_logits(PRESET, adapter, 8, 1.0, fz, tr, toks, jnp.int32(0))
        assert float(jnp.abs(out - base).max()) > 1e-4

    def test_padding_positions_do_not_affect_logits(self):
        fz = make_frozen()
        tr = make_trainable("metatt4d", 8, zero_first=False)
        toks = tokens_batch()
        logits1 = model.task_logits(PRESET, "metatt4d", 8, 1.0, fz, tr, toks, jnp.int32(0))
        # PAD ids are PAD everywhere; embeddings of PAD are fixed, but the
        # attention mask must stop non-PAD positions from attending to PAD.
        # Check CLS logits do not change when PAD count changes content via
        # attention: replace one non-pad token far from CLS instead.
        toks2 = toks.at[:, 10].set(toks[:, 10] + 1)
        logits2 = model.task_logits(PRESET, "metatt4d", 8, 1.0, fz, tr, toks2, jnp.int32(0))
        assert float(jnp.abs(logits1 - logits2).max()) > 0.0  # content matters

    def test_task_id_switches_head_and_core(self):
        fz = make_frozen(tasks=3)
        tr = make_trainable("metatt4p1d", 8, tasks=3, zero_first=False)
        toks = tokens_batch()
        l0 = model.task_logits(PRESET, "metatt4p1d", 8, 1.0, fz, tr, toks, jnp.int32(0))
        l2 = model.task_logits(PRESET, "metatt4p1d", 8, 1.0, fz, tr, toks, jnp.int32(2))
        assert float(jnp.abs(l0 - l2).max()) > 1e-4


class TestLosses:
    def test_classification_loss_weighted(self):
        logits = jnp.array([[10.0, -10.0], [10.0, -10.0]])
        labels = jnp.array([0, 1])
        w_both = model.task_loss(logits, labels, jnp.zeros(2), jnp.array([1.0, 1.0]), 2)
        w_first = model.task_loss(logits, labels, jnp.zeros(2), jnp.array([1.0, 0.0]), 2)
        assert float(w_first) < 1e-3  # correct, confident
        assert float(w_both) > 5.0  # second is maximally wrong
    def test_regression_loss(self):
        logits = jnp.array([[0.5], [1.0]])
        scores = jnp.array([2.5, 5.0])  # /5 -> 0.5, 1.0 — exact
        loss = model.task_loss(logits, jnp.zeros(2, jnp.int32), scores, jnp.ones(2), 1)
        assert float(loss) < 1e-9

    def test_mlm_loss_prefers_correct_token(self):
        tr = {name: arr for name, arr in model.init_encoder_weights(PRESET, seed=3)}
        toks = tokens_batch()
        targets = toks
        mask = jnp.ones((B, S), jnp.float32)
        loss = model.mlm_loss(PRESET, tr, toks, targets, mask)
        # ln(vocab) is the chance level; a fresh model should be near it.
        assert 0.3 * np.log(P["vocab"]) < float(loss) < 3.0 * np.log(P["vocab"])


class TestStepBuilders:
    def _materialize(self, inputs, seed=0):
        key = jax.random.PRNGKey(seed)
        args = []
        for name, shape, dtype in inputs:
            key, sub = jax.random.split(key)
            if dtype == "i32":
                if name == "tokens":
                    args.append(tokens_batch())
                elif name in ("labels", "targets"):
                    args.append(jnp.zeros(shape, jnp.int32))
                else:  # task_id
                    args.append(jnp.zeros(shape, jnp.int32))
            else:
                if name == "alpha":
                    args.append(jnp.float32(1.0))
                elif name in ("weights", "mask"):
                    args.append(jnp.ones(shape, jnp.float32))
                else:
                    args.append(jax.random.normal(sub, shape, jnp.float32) * 0.05)
        return args

    @pytest.mark.parametrize("adapter", ["metatt4d", "lora"])
    def test_train_step_outputs_match_spec(self, adapter):
        fn, inputs, outputs, nf, nt = model.build_train_step(
            PRESET, adapter, 4, 2, 1, B, S
        )
        args = self._materialize(inputs)
        outs = fn(*args)
        assert len(outs) == len(outputs)
        for out, (name, shape, _) in zip(outs, outputs):
            assert out.shape == tuple(shape), name
        assert bool(jnp.isfinite(outs[0]))
        # grads flow: at least one grad array nonzero
        assert any(float(jnp.abs(o).max()) > 0 for o in outs[1:])

    def test_eval_step_logits(self):
        fn, inputs, outputs, nf, nt = model.build_eval_step(
            PRESET, "metatt4d", 4, 3, 1, B, S
        )
        outs = fn(*self._materialize(inputs))
        assert outs[0].shape == (B, 3)

    def test_pretrain_step_grad_count(self):
        fn, inputs, outputs, nf, nt = model.build_pretrain_step(PRESET, B, S)
        assert nf == 0 and nt == 20
        outs = fn(*self._materialize(inputs))
        assert len(outs) == 21  # loss + 20 grads
        # embeddings get gradient through the tied MLM head
        grad_tok = outs[1]
        assert float(jnp.abs(grad_tok).max()) > 0

    def test_train_grads_are_zero_only_where_expected(self):
        # With g1 == 0, grads w.r.t. g2/g3 are zero (they only appear in
        # products with g1-paths on both sides), but g1's grad is nonzero.
        fn, inputs, outputs, nf, nt = model.build_train_step(
            PRESET, "metatt4d", 4, 2, 1, B, S
        )
        args = self._materialize(inputs)
        # zero out g1 (first trainable input)
        g1_idx = nf
        assert inputs[g1_idx][0] == "g1"
        args[g1_idx] = jnp.zeros_like(args[g1_idx])
        outs = fn(*args)
        names = [o[0] for o in outputs]
        grads = dict(zip(names[1:], outs[1:]))
        assert float(jnp.abs(grads["grad_g1"]).max()) > 0
        assert float(jnp.abs(grads["grad_g2"]).max()) == 0.0
        assert float(jnp.abs(grads["grad_g3"]).max()) == 0.0

    def test_full_ft_trains_encoder(self):
        fn, inputs, outputs, nf, nt = model.build_train_step(
            PRESET, "full", 0, 2, 1, B, S
        )
        assert nf == 2 and nt == 20  # heads frozen, encoder trainable
        outs = fn(*self._materialize(inputs))
        assert len(outs) == 21


class TestSpecsMirrorRust:
    """Pin the layouts the rust side hard-codes (adapters/mod.rs)."""

    def test_metatt4d_spec(self):
        specs = model.adapter_param_specs("metatt4d", "tiny", 8, 1)
        assert [(n, s) for n, s in specs] == [
            ("g1", (64, 8)), ("g2", (4, 8, 8)), ("g3", (2, 8, 8)), ("g4", (8, 64)),
        ]

    def test_metatt5d_spec(self):
        specs = model.adapter_param_specs("metatt5d", "tiny", 4, 1)
        assert specs == [
            ("g1", (64, 4)), ("g2", (4, 4, 4)), ("g3", (2, 4, 4)),
            ("g4", (4, 4, 4)), ("g5", (4, 16)),
        ]

    def test_param_counts_match_paper_formulas(self):
        d, l, m, h = 64, 4, 2, 4
        for adapter, rank, want in [
            ("metatt4d", 8, 2 * d * 8 + (l + m) * 64),
            ("metatt5d", 4, (d + d // h) * 4 + (l + m + h) * 16),
            ("lora", 8, 2 * l * m * d * 8),
            ("lotr", 8, 2 * d * 8 + l * m * 64),
            ("vera", 64, l * m * (d + 64)),
        ]:
            specs = model.adapter_param_specs(adapter, "tiny", rank, 1)
            got = sum(int(np.prod(s)) for _, s in specs)
            assert got == want, adapter
