"""AOT driver: lowering, manifest correctness, freshness hashing."""

import json
import os
import tempfile

import pytest

from compile import aot, model


class TestPlan:
    def test_default_plan_covers_every_experiment(self):
        reqs = aot.default_plan()
        stems = {r.stem for r in reqs}
        assert len(stems) == len(reqs), "duplicate artifact stems"
        # Pretraining for tiny and small.
        assert any(r.step == "pretrain" and r.preset == "tiny" for r in reqs)
        assert any(r.step == "pretrain" and r.preset == "small" for r in reqs)
        # Table 1: every adapter appears with train+eval at 2 classes.
        for adapter in ("metatt4d", "metatt5d", "lora", "vera", "lotr", "full"):
            assert any(
                r.step == "train" and r.adapter == adapter and r.classes == 2
                for r in reqs
            ), adapter
        # Regression (STS-B) and 3-class (MNLI) variants exist.
        assert any(r.classes == 1 and r.step == "train" for r in reqs)
        assert any(r.classes == 3 and r.step == "train" for r in reqs)
        # DMRG ladder: metatt5d at every rank 4..10.
        for rank in range(4, 11):
            assert any(
                r.adapter == "metatt5d" and r.rank == rank and r.classes == 2
                for r in reqs
            ), f"missing 5d rank {rank}"
        # MTL artifacts at 3 and 4 tasks.
        for tasks in (3, 4):
            for adapter in ("metatt4p1d", "metatt4d", "lora"):
                assert any(
                    r.adapter == adapter and r.tasks == tasks for r in reqs
                ), (adapter, tasks)
        # Pallas serve kernels.
        assert any(r.step == "apply" and r.adapter == "metatt4d" for r in reqs)
        assert any(r.step == "apply" and r.adapter == "lora" for r in reqs)

    def test_with_base_adds_base_sim(self):
        base = aot.default_plan(with_base=True)
        assert any(r.preset == "base_sim" and r.step == "pretrain" for r in base)
        assert any(r.preset == "base_sim" and r.step == "train" for r in base)

    def test_plan_hash_is_stable_and_plan_sensitive(self):
        reqs = aot.default_plan()
        assert aot.plan_hash(reqs) == aot.plan_hash(reqs)
        assert aot.plan_hash(reqs) != aot.plan_hash(reqs[:-1])


class TestLowering:
    def test_lower_one_writes_valid_entry(self):
        req = aot.Request("eval", "tiny", "metatt4d", 4, 2, 1, 2, 32)
        with tempfile.TemporaryDirectory() as d:
            entry, nbytes = aot.lower_one(req, d)
            path = os.path.join(d, entry["file"])
            assert os.path.exists(path) and nbytes > 1000
            text = open(path).read()
            assert text.startswith("HloModule")
            # I/O layout matches the model's specs.
            n_inputs = len(entry["inputs"])
            sfz = model.frozen_specs("tiny", 1, 2)
            stry = model.adapter_param_specs("metatt4d", "tiny", 4, 1)
            assert entry["n_frozen"] == len(sfz)
            assert entry["n_trainable"] == len(stry)
            # frozen..., trainable..., tokens, task_id, alpha
            assert n_inputs == len(sfz) + len(stry) + 3
            assert entry["inputs"][0]["name"] == "tok_emb"
            assert entry["inputs"][-1]["name"] == "alpha"
            assert entry["outputs"][0]["name"] == "logits"
            assert entry["outputs"][0]["shape"] == [2, 2]
            # The HLO entry computation has exactly n_inputs parameters —
            # keep_unused=True must stop jax from pruning unused args (e.g.
            # `scores` in classification artifacts), or the rust call
            # convention breaks.
            import re
            entry = text.split("ENTRY")[1]
            params = re.findall(r"parameter\((\d+)\)", entry)
            assert len(set(params)) == n_inputs, (len(set(params)), n_inputs)

    def test_train_entry_grad_outputs(self):
        req = aot.Request("train", "tiny", "lora", 4, 2, 1, 2, 32)
        with tempfile.TemporaryDirectory() as d:
            entry, _ = aot.lower_one(req, d)
            names = [o["name"] for o in entry["outputs"]]
            assert names == ["loss", "grad_lora_a", "grad_lora_b"]
            assert entry["outputs"][1]["shape"] == [4, 2, 64, 4]

    def test_train_entry_keeps_unused_inputs(self):
        # Classification train steps never read `scores`; regression ones
        # never read `labels` — both must still be HLO parameters.
        import re
        for classes in (1, 2):
            req = aot.Request("train", "tiny", "metatt4d", 4, classes, 1, 2, 32)
            with tempfile.TemporaryDirectory() as d:
                entry, _ = aot.lower_one(req, d)
                text = open(os.path.join(d, entry["file"])).read()
                body = text.split("ENTRY")[1]
                params = set(re.findall(r"parameter\((\d+)\)", body))
                assert len(params) == len(entry["inputs"]), classes
