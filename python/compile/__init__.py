"""Build-time compile path: JAX model (L2) + Pallas kernels (L1) + AOT
lowering to HLO text artifacts consumed by the rust coordinator (L3).

Nothing in this package runs at training/serving time — `make artifacts`
invokes `python -m compile.aot` once and the rust binary is self-contained
afterwards.
"""
