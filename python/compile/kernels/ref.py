"""Pure-jnp oracles for the L1 Pallas kernels.

These are the ground-truth implementations the pytest suite pins the Pallas
kernels against (atol/rtol 1e-5), and the implementations the *train-step*
artifacts lower (pallas_call has no VJP in interpret mode; see DESIGN.md
"Autodiff note"). The apply/serve artifacts lower the Pallas kernels, so the
kernel == ref check is what guarantees trained math == served math.
"""

import jax.numpy as jnp


def tt_apply_ref(x, g1, mid, g4, alpha):
    """MetaTT-4D adapter application for one (layer, matrix) pair.

    y = alpha * (((x @ g1) @ mid) @ g4)        (paper Eq. 5)

    Args:
      x:   (n, d_in) activations.
      g1:  (d_in, r) left boundary core.
      mid: (r, r) pre-contracted middle slice G2[l] @ G3[m].
      g4:  (r, d_out) right boundary core.
      alpha: python float scaling.
    """
    return alpha * (((x @ g1) @ mid) @ g4)


def tt_apply_5d_ref(x, g1, mid, g4h, g5, alpha):
    """MetaTT-5D adapter application for one (layer, matrix) pair.

    Per head h: y_h = alpha * (x @ g1 @ mid @ g4h[h] @ g5), concatenated
    along the output axis (paper Eq. 3 / Fig. 1 right).

    Args:
      x:   (n, d_in)
      g1:  (d_in, r)
      mid: (r, r)          -- G2[l] @ G3[m]
      g4h: (h, r, r)       -- head core
      g5:  (r, d_out // h) -- right boundary
    """
    xm = (x @ g1) @ mid                            # (n, r)
    per_head = jnp.einsum("nr,hrq->nhq", xm, g4h)  # (n, h, r)
    y = jnp.einsum("nhq,qd->nhd", per_head, g5)    # (n, h, dh)
    n = x.shape[0]
    return alpha * y.reshape(n, -1)


def lora_apply_ref(x, a, b, alpha):
    """LoRA adapter application: y = alpha * ((x @ a) @ b)."""
    return alpha * ((x @ a) @ b)
