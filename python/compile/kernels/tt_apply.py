"""L1 Pallas kernels: fused MetaTT / LoRA adapter application.

The MetaTT hot-spot is the four-GEMM chain of paper Eq. 5,

    y = alpha * (((x @ G1) @ (G2[l] @ G3[m])) @ G4)

The paper's implementation runs it as cuBLAS GEMMs on A100. On TPU the
right shape is different (DESIGN.md §Hardware-Adaptation): tile the token
axis into VMEM-resident blocks streamed from HBM, keep the small factors
(G1: d×r, mid: r×r, G4: r×d, a few hundred KB at most) resident in VMEM
across the whole grid, and fuse the chain so the (blk_n × r) intermediate
never leaves VMEM. `BlockSpec` below expresses exactly that schedule:
`x`/`y` are blocked over the grid's token axis; the factor operands use a
constant index_map so every grid step sees the whole factor.

Kernels run with `interpret=True` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode lowers to plain HLO so the same
artifact runs anywhere. Real-TPU efficiency is *estimated* analytically by
`analyze()` (VMEM footprint + MXU utilization), not measured from
interpret-mode wallclock.

Correctness: pytest pins every kernel against `ref.py` including a
hypothesis-style randomized shape/dtype sweep (python/tests/test_kernels.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Token-axis block: 128 rows keeps x-tile + intermediates < 1 MB for d <= 1024
# while filling the 128-lane MXU dimension.
DEFAULT_BLOCK_N = 128


def _tt_kernel(x_ref, g1_ref, mid_ref, g4_ref, o_ref, *, alpha):
    """One grid step: y_blk = alpha * (((x_blk @ G1) @ mid) @ G4).

    All four GEMMs run back-to-back on the same VMEM-resident block; the
    (blk_n, r) intermediates never round-trip to HBM. `preferred_element_type`
    pins f32 accumulation (MXU-friendly if inputs were bf16).
    """
    x = x_ref[...]
    t = jnp.dot(x, g1_ref[...], preferred_element_type=jnp.float32)
    t = jnp.dot(t, mid_ref[...], preferred_element_type=jnp.float32)
    t = jnp.dot(t, g4_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (alpha * t).astype(o_ref.dtype)


def tt_apply(x, g1, mid, g4, alpha, block_n=DEFAULT_BLOCK_N):
    """Fused MetaTT-4D adapter application (Pallas).

    Args:
      x:   (n, d_in); n must be a multiple of block_n or smaller than it.
      g1:  (d_in, r)
      mid: (r, r) pre-contracted G2[l] @ G3[m]
      g4:  (r, d_out)
      alpha: python float.
    Returns:
      (n, d_out) adapter output.
    """
    n, d_in = x.shape
    d_out = g4.shape[1]
    blk = min(block_n, n)
    if n % blk != 0:
        raise ValueError(f"n={n} not divisible by block {blk}")
    grid = (n // blk,)
    return pl.pallas_call(
        functools.partial(_tt_kernel, alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, d_in), lambda i: (i, 0)),
            pl.BlockSpec(g1.shape, lambda i: (0, 0)),
            pl.BlockSpec(mid.shape, lambda i: (0, 0)),
            pl.BlockSpec(g4.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d_out), x.dtype),
        interpret=True,
    )(x, g1, mid, g4)


def _tt5d_kernel(x_ref, g1_ref, mid_ref, g4h_ref, g5_ref, o_ref, *, alpha):
    """5D variant: per-head right factors, outputs concatenated over heads.

    The head loop is unrolled at trace time (h is static); each head's
    (blk_n, r) @ (r, r) @ (r, dh) chain stays in VMEM.
    """
    x = x_ref[...]
    xm = jnp.dot(x, g1_ref[...], preferred_element_type=jnp.float32)
    xm = jnp.dot(xm, mid_ref[...], preferred_element_type=jnp.float32)
    h = g4h_ref.shape[0]
    outs = []
    for head in range(h):
        t = jnp.dot(xm, g4h_ref[head], preferred_element_type=jnp.float32)
        outs.append(jnp.dot(t, g5_ref[...], preferred_element_type=jnp.float32))
    o_ref[...] = (alpha * jnp.concatenate(outs, axis=-1)).astype(o_ref.dtype)


def tt_apply_5d(x, g1, mid, g4h, g5, alpha, block_n=DEFAULT_BLOCK_N):
    """Fused MetaTT-5D adapter application (Pallas)."""
    n, d_in = x.shape
    h, r, _ = g4h.shape
    dh = g5.shape[1]
    d_out = h * dh
    blk = min(block_n, n)
    if n % blk != 0:
        raise ValueError(f"n={n} not divisible by block {blk}")
    grid = (n // blk,)
    return pl.pallas_call(
        functools.partial(_tt5d_kernel, alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, d_in), lambda i: (i, 0)),
            pl.BlockSpec(g1.shape, lambda i: (0, 0)),
            pl.BlockSpec(mid.shape, lambda i: (0, 0)),
            pl.BlockSpec(g4h.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(g5.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d_out), x.dtype),
        interpret=True,
    )(x, g1, mid, g4h, g5)


def _lora_kernel(x_ref, a_ref, b_ref, o_ref, *, alpha):
    x = x_ref[...]
    t = jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)
    t = jnp.dot(t, b_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (alpha * t).astype(o_ref.dtype)


def lora_apply(x, a, b, alpha, block_n=DEFAULT_BLOCK_N):
    """Fused LoRA apply (baseline kernel): y = alpha * ((x @ a) @ b)."""
    n, d_in = x.shape
    d_out = b.shape[1]
    blk = min(block_n, n)
    if n % blk != 0:
        raise ValueError(f"n={n} not divisible by block {blk}")
    return pl.pallas_call(
        functools.partial(_lora_kernel, alpha=alpha),
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk, d_in), lambda i: (i, 0)),
            pl.BlockSpec(a.shape, lambda i: (0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d_out), x.dtype),
        interpret=True,
    )(x, a, b)


# ---------------------------------------------------------------------------
# Analytic TPU-efficiency model (DESIGN.md §Hardware-Adaptation).
# ---------------------------------------------------------------------------

VMEM_BYTES = 16 * 1024 * 1024  # v4-class core
MXU_DIM = 128


def analyze(n, d, r, block_n=DEFAULT_BLOCK_N, bytes_per_el=4):
    """VMEM footprint + MXU utilization estimate for tt_apply at (n, d, r).

    Returns a dict with:
      vmem_bytes        — resident factor + per-block working set.
      vmem_frac         — fraction of a 16 MB VMEM.
      flops             — total useful FLOPs of the fused chain.
      hbm_bytes         — HBM traffic (x in, y out, factors once).
      arith_intensity   — flops / hbm_bytes.
      mxu_util          — utilization of 128×128 MXU tiles by the dominant
                          GEMMs (d-dim full tiles; r-dim padded to 128).
    """
    blk = min(block_n, n)
    resident = (d * r + r * r + r * d) * bytes_per_el          # G1, mid, G4
    working = (blk * d * 2 + blk * r * 2) * bytes_per_el       # x, y, 2 temps
    vmem = resident + working
    flops = 2 * n * (d * r + r * r + r * d)
    hbm = (n * d * 2 + d * r * 2 + r * r) * bytes_per_el
    # The boundary GEMMs (n×d @ d×r) dominate: tiles are (128 × d-tile) @
    # (d-tile × r). The r output dim occupies r/128 of the MXU columns.
    mxu_util = min(1.0, r / MXU_DIM) * min(1.0, blk / MXU_DIM)
    return {
        "vmem_bytes": vmem,
        "vmem_frac": vmem / VMEM_BYTES,
        "flops": flops,
        "hbm_bytes": hbm,
        "arith_intensity": flops / hbm,
        "mxu_util": mxu_util,
    }


def main():
    print("tt_apply TPU estimates (f32):")
    print(f"{'n':>6} {'d':>6} {'r':>4} {'vmem':>10} {'AI':>7} {'mxu':>5}")
    for d in (256, 768, 1024):
        for r in (8, 16, 32, 64):
            a = analyze(4096, d, r)
            print(
                f"{4096:>6} {d:>6} {r:>4} {a['vmem_bytes']/1024:>8.0f}KB"
                f" {a['arith_intensity']:>7.2f} {a['mxu_util']:>5.2f}"
            )
    print(
        "\nNote: the chain is HBM-bound in x for r << d (AI ≈ r); fusing all"
        "\nfour GEMMs (this kernel) is what keeps the r-sized intermediates"
        "\noff HBM — unfused, AI drops by ~2x and traffic doubles."
    )


if __name__ == "__main__":
    main()
