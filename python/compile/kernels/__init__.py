"""L1 kernels: Pallas implementations (`tt_apply`) and jnp oracles (`ref`)."""
