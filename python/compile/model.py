"""L2: the JAX transformer encoder with global MetaTT adapters (build time).

A from-scratch RoBERTa-style encoder (post-LN, GELU MLP, learned positions,
CLS pooling) whose Q and V projections are steered by one of the adapter
families of the paper's Table 1: MetaTT-4D / 5D / (4+1)D, LoRA, VeRA, LoTR,
or full fine-tuning. The module defines, as *the single source of truth
shared with the rust side* (rust/src/adapters/mod.rs must mirror it):

  * `MODEL_PRESETS`            — model size presets (== `config::ModelPreset`)
  * `frozen_specs`             — ordered frozen-weight layout
  * `adapter_param_specs`      — ordered trainable-adapter layout
  * train / eval / pretrain step functions lowered by `aot.py`

Everything is positional: step functions take `(frozen..., trainable...,
data...)` in spec order, so the HLO parameter order is deterministic and the
manifest can describe it exactly.

The adapter application in the train path uses the jnp reference math
(`kernels.ref`) — identical to the Pallas kernels by pytest — because
`pallas_call` has no VJP in interpret mode. The serve/apply artifacts lower
the Pallas kernels themselves.
"""

import math

import jax
import jax.numpy as jnp

from .kernels import ref

MODEL_PRESETS = {
    "tiny": dict(hidden=64, layers=4, heads=4, ffn=256, vocab=512, max_seq=32),
    "small": dict(hidden=128, layers=6, heads=8, ffn=512, vocab=1024, max_seq=64),
    "base_sim": dict(hidden=256, layers=12, heads=8, ffn=1024, vocab=1024, max_seq=64),
}

# Adapted projection matrices per layer: m=0 -> Q, m=1 -> V (paper App. A.2:
# Q,V is the configuration used for all Table-1 results).
N_MATRICES = 2

PAD_ID = 0


# ---------------------------------------------------------------------------
# Parameter layouts (shared contract with rust).
# ---------------------------------------------------------------------------


def frozen_specs(preset, tasks, classes):
    """Ordered frozen-weight layout: 20 encoder arrays + per-task heads."""
    p = MODEL_PRESETS[preset]
    d, l, f = p["hidden"], p["layers"], p["ffn"]
    v, s = p["vocab"], p["max_seq"]
    return [
        ("tok_emb", (v, d)),
        ("pos_emb", (s, d)),
        ("emb_ln_g", (d,)),
        ("emb_ln_b", (d,)),
        ("wq", (l, d, d)),
        ("bq", (l, d)),
        ("wk", (l, d, d)),
        ("bk", (l, d)),
        ("wv", (l, d, d)),
        ("bv", (l, d)),
        ("wo", (l, d, d)),
        ("bo", (l, d)),
        ("ln1_g", (l, d)),
        ("ln1_b", (l, d)),
        ("w1", (l, d, f)),
        ("b1", (l, f)),
        ("w2", (l, f, d)),
        ("b2", (l, d)),
        ("ln2_g", (l, d)),
        ("ln2_b", (l, d)),
        ("cls_w", (tasks, d, classes)),
        ("cls_b", (tasks, classes)),
    ]


def encoder_specs(preset):
    """The 20 encoder arrays (frozen_specs minus the classifier heads) —
    the trainable set for pretraining and full fine-tuning."""
    return frozen_specs(preset, 1, 1)[:-2]


def adapter_param_specs(adapter, preset, rank, tasks):
    """Ordered trainable layout per adapter — mirrors
    `AdapterSpec::param_specs` in rust/src/adapters/mod.rs."""
    p = MODEL_PRESETS[preset]
    d, l, h = p["hidden"], p["layers"], p["heads"]
    m, r, t = N_MATRICES, rank, tasks
    if adapter == "metatt4d":
        return [("g1", (d, r)), ("g2", (l, r, r)), ("g3", (m, r, r)), ("g4", (r, d))]
    if adapter == "metatt5d":
        return [
            ("g1", (d, r)),
            ("g2", (l, r, r)),
            ("g3", (m, r, r)),
            ("g4", (h, r, r)),
            ("g5", (r, d // h)),
        ]
    if adapter == "metatt4p1d":
        return [
            ("g1", (d, r)),
            ("g2", (l, r, r)),
            ("g3", (t, r, r)),
            ("g4", (m, r, r)),
            ("g5", (r, d)),
        ]
    if adapter == "lora":
        return [("lora_a", (l, m, d, r)), ("lora_b", (l, m, r, d))]
    if adapter == "vera":
        return [("vera_d", (l, m, r)), ("vera_b", (l, m, d))]
    if adapter == "lotr":
        return [("lotr_u", (d, r)), ("lotr_s", (l, m, r, r)), ("lotr_v", (r, d))]
    if adapter == "full":
        return encoder_specs(preset)
    raise ValueError(f"unknown adapter '{adapter}'")


# ---------------------------------------------------------------------------
# Adapter application.
# ---------------------------------------------------------------------------


def _vera_frozen(d, r, seed=7):
    """VeRA's frozen shared random projections, baked into the HLO as
    constants (seed-fixed, so every artifact agrees)."""
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(ka, (d, r), jnp.float32) / math.sqrt(d)
    b = jax.random.normal(kb, (r, d), jnp.float32) / math.sqrt(r)
    return a, b


def adapter_delta(adapter, tr, layer, matrix, task_id, x2d, alpha, preset, rank):
    """Adapter output for activations `x2d` (n, d) at (layer, matrix).

    `tr` is the trainable dict; `task_id` a traced scalar (used by the
    (4+1)D task core). Returns (n, d)."""
    p = MODEL_PRESETS[preset]
    d = p["hidden"]
    if adapter == "metatt4d":
        mid = tr["g2"][layer] @ tr["g3"][matrix]
        return ref.tt_apply_ref(x2d, tr["g1"], mid, tr["g4"], alpha)
    if adapter == "metatt5d":
        mid = tr["g2"][layer] @ tr["g3"][matrix]
        return ref.tt_apply_5d_ref(x2d, tr["g1"], mid, tr["g4"], tr["g5"], alpha)
    if adapter == "metatt4p1d":
        g3t = jnp.take(tr["g3"], task_id, axis=0)  # dynamic task slice
        mid = tr["g2"][layer] @ g3t @ tr["g4"][matrix]
        return ref.tt_apply_ref(x2d, tr["g1"], mid, tr["g5"], alpha)
    if adapter == "lora":
        return ref.lora_apply_ref(
            x2d, tr["lora_a"][layer, matrix], tr["lora_b"][layer, matrix], alpha
        )
    if adapter == "vera":
        a, b = _vera_frozen(d, rank)
        t = (x2d @ a) * tr["vera_d"][layer, matrix][None, :]
        return alpha * ((t @ b) * tr["vera_b"][layer, matrix][None, :])
    if adapter == "lotr":
        mid = tr["lotr_s"][layer, matrix]
        return ref.tt_apply_ref(x2d, tr["lotr_u"], mid, tr["lotr_v"], alpha)
    if adapter == "full" or adapter == "none":
        return jnp.zeros_like(x2d)
    raise ValueError(f"unknown adapter '{adapter}'")


# ---------------------------------------------------------------------------
# Encoder forward.
# ---------------------------------------------------------------------------


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def encoder_forward(preset, adapter, rank, alpha, fz, tr, tokens, task_id):
    """Run the encoder; returns hidden states (b, s, d).

    `fz`/`tr` are dicts of frozen/trainable arrays. For `adapter == "full"`,
    the encoder weights themselves come from `tr`.
    """
    p = MODEL_PRESETS[preset]
    d, l, h = p["hidden"], p["layers"], p["heads"]
    dh = d // h
    w = tr if adapter == "full" else fz  # encoder weight source
    b, s = tokens.shape

    x = w["tok_emb"][tokens] + w["pos_emb"][None, :s, :]
    x = _layer_norm(x, w["emb_ln_g"], w["emb_ln_b"])

    pad_mask = (tokens != PAD_ID)  # (b, s)
    att_bias = jnp.where(pad_mask[:, None, None, :], 0.0, -1e9)  # (b,1,1,s)

    def delta(layer, matrix, x3d):
        x2d = x3d.reshape(b * s, d)
        out = adapter_delta(
            adapter, tr, layer, matrix, task_id, x2d, alpha, preset, rank
        )
        return out.reshape(b, s, d)

    for layer in range(l):
        # --- Multi-head self-attention, adapters on Q (m=0) and V (m=1).
        q = x @ w["wq"][layer] + w["bq"][layer] + delta(layer, 0, x)
        k = x @ w["wk"][layer] + w["bk"][layer]
        v = x @ w["wv"][layer] + w["bv"][layer] + delta(layer, 1, x)
        q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        k = k.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        v = v.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(dh) + att_bias
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, d)
        attn_out = ctx @ w["wo"][layer] + w["bo"][layer]
        x = _layer_norm(x + attn_out, w["ln1_g"][layer], w["ln1_b"][layer])
        # --- MLP.
        m_out = jax.nn.gelu(x @ w["w1"][layer] + w["b1"][layer])
        m_out = m_out @ w["w2"][layer] + w["b2"][layer]
        x = _layer_norm(x + m_out, w["ln2_g"][layer], w["ln2_b"][layer])
    return x


def task_logits(preset, adapter, rank, alpha, fz, tr, tokens, task_id):
    """CLS-pooled task logits (b, classes) through the frozen head."""
    hidden = encoder_forward(preset, adapter, rank, alpha, fz, tr, tokens, task_id)
    pooled = hidden[:, 0, :]  # CLS
    cw = jnp.take(fz["cls_w"], task_id, axis=0)
    cb = jnp.take(fz["cls_b"], task_id, axis=0)
    return pooled @ cw + cb


# ---------------------------------------------------------------------------
# Losses.
# ---------------------------------------------------------------------------


def task_loss(logits, labels, scores, weights, classes):
    """Weighted task loss: softmax CE for classification, MSE for the
    regression analogue (classes == 1; targets in [0, 5] scaled to [0,1])."""
    wsum = jnp.maximum(jnp.sum(weights), 1e-6)
    if classes == 1:
        pred = logits[:, 0]
        per = (pred - scores / 5.0) ** 2
    else:
        logp = jax.nn.log_softmax(logits, axis=-1)
        per = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.sum(per * weights) / wsum


def mlm_loss(preset, tr, tokens, targets, mask):
    """Masked-LM loss with weight-tied output head (logits = h @ tok_embᵀ)."""
    hidden = encoder_forward(preset, "full", 0, 0.0, {}, tr, tokens, jnp.int32(0))
    logits = hidden @ tr["tok_emb"].T  # (b, s, v)
    logp = jax.nn.log_softmax(logits, axis=-1)
    per = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(per * mask) / jnp.maximum(jnp.sum(mask), 1e-6)


# ---------------------------------------------------------------------------
# Step builders (lowered by aot.py).
# ---------------------------------------------------------------------------


def _to_dicts(specs_fz, specs_tr, args):
    nf, nt = len(specs_fz), len(specs_tr)
    fz = {name: arg for (name, _), arg in zip(specs_fz, args[:nf])}
    tr = {name: arg for (name, _), arg in zip(specs_tr, args[nf : nf + nt])}
    return fz, tr, args[nf + nt :]


def build_train_step(preset, adapter, rank, classes, tasks, batch, seq):
    """fwd+bwd step: (frozen..., trainable..., tokens, labels, scores,
    weights, task_id, alpha) -> (loss, grad_per_trainable...).

    `alpha` is a scalar *input*, so one artifact serves the whole
    hyper-parameter grid of paper Appendix D."""
    sfz = frozen_specs(preset, tasks, classes)
    if adapter == "full":
        sfz = sfz[-2:]  # only the heads stay frozen
    stry = adapter_param_specs(adapter, preset, rank, tasks)

    def step(*args):
        fz, tr, data = _to_dicts(sfz, stry, args)
        tokens, labels, scores, weights, task_id, alpha = data

        def loss_fn(tr_):
            logits = task_logits(preset, adapter, rank, alpha, fz, tr_, tokens, task_id)
            return task_loss(logits, labels, scores, weights, classes)

        loss, grads = jax.value_and_grad(loss_fn)(tr)
        return (loss,) + tuple(grads[name] for name, _ in stry)

    inputs = _input_specs(sfz, stry, batch, seq, with_labels=True)
    outputs = [("loss", (), "f32")] + [
        (f"grad_{name}", shape, "f32") for name, shape in stry
    ]
    return step, inputs, outputs, len(sfz), len(stry)


def build_eval_step(preset, adapter, rank, classes, tasks, batch, seq):
    """fwd step: (frozen..., trainable..., tokens, task_id, alpha) -> logits."""
    sfz = frozen_specs(preset, tasks, classes)
    if adapter == "full":
        sfz = sfz[-2:]
    stry = adapter_param_specs(adapter, preset, rank, tasks)

    def step(*args):
        fz, tr, data = _to_dicts(sfz, stry, args)
        tokens, task_id, alpha = data
        return (task_logits(preset, adapter, rank, alpha, fz, tr, tokens, task_id),)

    inputs = _input_specs(sfz, stry, batch, seq, with_labels=False)
    outputs = [("logits", (batch, classes), "f32")]
    return step, inputs, outputs, len(sfz), len(stry)


def build_pretrain_step(preset, batch, seq):
    """MLM step over all encoder weights: (weights..., tokens, targets,
    mask) -> (loss, grads...)."""
    stry = encoder_specs(preset)

    def step(*args):
        _, tr, data = _to_dicts([], stry, args)
        tokens, targets, mask = data

        def loss_fn(tr_):
            return mlm_loss(preset, tr_, tokens, targets, mask)

        loss, grads = jax.value_and_grad(loss_fn)(tr)
        return (loss,) + tuple(grads[name] for name, _ in stry)

    inputs = [(name, shape, "f32") for name, shape in stry] + [
        ("tokens", (batch, seq), "i32"),
        ("targets", (batch, seq), "i32"),
        ("mask", (batch, seq), "f32"),
    ]
    outputs = [("loss", (), "f32")] + [
        (f"grad_{name}", shape, "f32") for name, shape in stry
    ]
    return step, inputs, outputs, 0, len(stry)


def build_apply_step(preset, adapter, rank, alpha, batch, seq):
    """Serving hot-path artifact: the *Pallas* fused adapter apply for one
    (layer, matrix) slice — inputs are the pre-contracted factors."""
    from .kernels import tt_apply as k

    p = MODEL_PRESETS[preset]
    d = p["hidden"]
    n = batch * seq
    if adapter == "lora":
        def step(x, a, b_):
            return (k.lora_apply(x, a, b_, alpha),)

        inputs = [
            ("x", (n, d), "f32"),
            ("lora_a", (d, rank), "f32"),
            ("lora_b", (rank, d), "f32"),
        ]
    else:
        def step(x, g1, mid, g4):
            return (k.tt_apply(x, g1, mid, g4, alpha),)

        inputs = [
            ("x", (n, d), "f32"),
            ("g1", (d, rank), "f32"),
            ("mid", (rank, rank), "f32"),
            ("g4", (rank, d), "f32"),
        ]
    outputs = [("y", (n, d), "f32")]
    return step, inputs, outputs, 0, len(inputs) - 1


def _input_specs(sfz, stry, batch, seq, with_labels):
    inputs = [(name, shape, "f32") for name, shape in sfz]
    inputs += [(name, shape, "f32") for name, shape in stry]
    inputs.append(("tokens", (batch, seq), "i32"))
    if with_labels:
        inputs += [
            ("labels", (batch,), "i32"),
            ("scores", (batch,), "f32"),
            ("weights", (batch,), "f32"),
        ]
    inputs.append(("task_id", (), "i32"))
    inputs.append(("alpha", (), "f32"))
    return inputs


# ---------------------------------------------------------------------------
# Frozen-weight initialization (pre-pretraining starting point).
# ---------------------------------------------------------------------------


def init_encoder_weights(preset, seed=0):
    """Fresh encoder weights (the state `metatt pretrain` starts from).
    Returned in `encoder_specs` order."""
    p = MODEL_PRESETS[preset]
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in encoder_specs(preset):
        key, sub = jax.random.split(key)
        if name.endswith(("_g", "ln1_g", "ln2_g")):
            arr = jnp.ones(shape, jnp.float32)
        elif name.startswith("b") or name.endswith("_b"):
            arr = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            arr = jax.random.normal(sub, shape, jnp.float32) * (0.02 if "emb" in name else 1.0 / math.sqrt(fan_in))
        out.append((name, arr))
    return out
