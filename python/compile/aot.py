"""AOT driver: lower every (model, adapter, rank, classes, tasks) step to
HLO text and write `artifacts/manifest.json` for the rust registry.

HLO *text* — not serialized HloModuleProto — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot [--out-dir ../artifacts] [--only SUBSTR]
                          [--with-base] [--list] [--force]

The build is a no-op when nothing changed: a hash of the compile/ sources
plus the build plan is stored next to the manifest and checked first.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the rust-loadable form)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


class Request:
    """One artifact to build."""

    def __init__(self, step, preset, adapter, rank, classes, tasks, batch, seq, alpha=1.0):
        self.step = step
        self.preset = preset
        self.adapter = adapter
        self.rank = rank
        self.classes = classes
        self.tasks = tasks
        self.batch = batch
        self.seq = seq
        self.alpha = alpha

    @property
    def stem(self):
        return (
            f"{self.step}_{self.preset}_{self.adapter}_r{self.rank}"
            f"_c{self.classes}_t{self.tasks}_b{self.batch}_s{self.seq}"
        )

    def build(self):
        """Returns (fn, inputs, outputs, n_frozen, n_trainable)."""
        if self.step == "train":
            return model.build_train_step(
                self.preset, self.adapter, self.rank,
                self.classes, self.tasks, self.batch, self.seq,
            )
        if self.step == "eval":
            return model.build_eval_step(
                self.preset, self.adapter, self.rank,
                self.classes, self.tasks, self.batch, self.seq,
            )
        if self.step == "pretrain":
            return model.build_pretrain_step(self.preset, self.batch, self.seq)
        if self.step == "apply":
            return model.build_apply_step(
                self.preset, self.adapter, self.rank, self.alpha, self.batch, self.seq
            )
        raise ValueError(f"unknown step {self.step}")


def default_plan(with_base=False):
    """The artifact grid the benches and examples consume.

    Alpha is a scalar input of train/eval artifacts (one artifact serves
    the whole Appendix-D hyper-parameter grid); only apply artifacts bake it.
    """
    reqs = []
    t = MODEL = "tiny"
    B, S = 16, model.MODEL_PRESETS[t]["max_seq"]

    # Pretraining (full-weights MLM) per preset.
    reqs.append(Request("pretrain", "tiny", "none", 0, 0, 0, 32, S))
    reqs.append(Request("pretrain", "small", "none", 0, 0, 0, 16, 64))
    if with_base:
        reqs.append(Request("pretrain", "base_sim", "none", 0, 0, 0, 8, 64))

    def add_pair(adapter, rank, classes, tasks=1, preset=MODEL, batch=B, seq=S):
        reqs.append(Request("train", preset, adapter, rank, classes, tasks, batch, seq))
        reqs.append(Request("eval", preset, adapter, rank, classes, tasks, batch, seq))

    # Table 1 grid (single task): every adapter at its table ranks, for
    # 2-class, 3-class (MNLI analogue) and regression (classes=1, STS-B).
    for classes in (1, 2, 3):
        for rank in (4, 8, 16):
            add_pair("metatt4d", rank, classes)
        add_pair("metatt5d", 8, classes)
        add_pair("lora", 8, classes)
        add_pair("vera", 64, classes)
        add_pair("lotr", 8, classes)
    add_pair("full", 0, 2)

    # DMRG rank ladder (Figs 2/6): MetaTT-5D on 2-class tasks, r 10 -> 4.
    for rank in (4, 5, 6, 7, 9, 10):
        add_pair("metatt5d", rank, 2)
    for rank in (5, 6, 10):  # 4D ladder for ablations
        add_pair("metatt4d", rank, 2)

    # MTL (Table 2 / Figs 4-5): 3-task and 4-task, 2-class heads.
    for tasks in (3, 4):
        add_pair("metatt4p1d", 8, 2, tasks=tasks)
        add_pair("metatt4d", 8, 2, tasks=tasks)
        add_pair("lora", 8, 2, tasks=tasks)

    # e2e example at the bigger preset.
    if with_base:
        add_pair("metatt4d", 8, 2, preset="base_sim", batch=8, seq=64)
    add_pair("metatt4d", 8, 2, preset="small", batch=16, seq=64)

    # Serving hot-path kernels (Pallas) for the micro-bench.
    reqs.append(Request("apply", "base_sim", "metatt4d", 8, 0, 0, 64, 64))
    reqs.append(Request("apply", "base_sim", "lora", 8, 0, 0, 64, 64))
    return reqs


def plan_hash(reqs):
    h = hashlib.sha256()
    here = os.path.dirname(os.path.abspath(__file__))
    for root, _, files in os.walk(here):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    for r in reqs:
        h.update(r.stem.encode())
    return h.hexdigest()


def lower_one(req, out_dir):
    fn, inputs, outputs, n_frozen, n_trainable = req.build()
    specs = [
        jax.ShapeDtypeStruct(shape, DTYPES[dtype]) for (_, shape, dtype) in inputs
    ]
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    text = to_hlo_text(lowered)
    fname = req.stem + ".hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    entry = {
        "step": req.step,
        "model": req.preset,
        "adapter": req.adapter,
        "rank": req.rank,
        "classes": req.classes,
        "tasks": req.tasks,
        "batch": req.batch,
        "seq": req.seq,
        "file": fname,
        "n_frozen": n_frozen,
        "n_trainable": n_trainable,
        "inputs": [
            {"name": n, "shape": list(s), "dtype": d} for (n, s, d) in inputs
        ],
        "outputs": [
            {"name": n, "shape": list(s), "dtype": d} for (n, s, d) in outputs
        ],
    }
    return entry, len(text)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="substring filter on artifact stems")
    ap.add_argument("--with-base", action="store_true", help="include base_sim artifacts")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true", help="rebuild even if fresh")
    args = ap.parse_args()

    reqs = default_plan(with_base=args.with_base)
    if args.only:
        reqs = [r for r in reqs if args.only in r.stem]
    if args.list:
        for r in reqs:
            print(r.stem)
        return

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    hash_path = os.path.join(out_dir, ".build_hash")
    want_hash = plan_hash(reqs)

    if not args.force and not args.only and os.path.exists(manifest_path) and os.path.exists(hash_path):
        with open(hash_path) as f:
            if f.read().strip() == want_hash:
                print(f"artifacts fresh ({len(reqs)} entries) — nothing to do")
                return

    # Merge with any pre-existing manifest so --only builds are incremental.
    entries = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            for e in json.load(f).get("artifacts", []):
                key = (e["step"], e["model"], e["adapter"], e["rank"],
                       e["classes"], e["tasks"], e["batch"], e["seq"])
                entries[key] = e

    total_bytes = 0
    for i, req in enumerate(reqs):
        entry, nbytes = lower_one(req, out_dir)
        total_bytes += nbytes
        key = (entry["step"], entry["model"], entry["adapter"], entry["rank"],
               entry["classes"], entry["tasks"], entry["batch"], entry["seq"])
        entries[key] = entry
        print(f"[{i+1}/{len(reqs)}] {req.stem} ({nbytes//1024} KB)")

    with open(manifest_path, "w") as f:
        json.dump({"artifacts": sorted(entries.values(), key=lambda e: e["file"])}, f, indent=1)
    if not args.only:
        with open(hash_path, "w") as f:
            f.write(want_hash)
    print(f"wrote {len(entries)} artifacts ({total_bytes//(1<<20)} MB) to {out_dir}")


if __name__ == "__main__":
    main()
